"""Deadline-aware micro-batching scheduler for the QueryServer (serving side
of the paper's batch-query architecture).

Many concurrent clients each carry a small per-request key set and a latency
budget; serving them one engine query at a time repays none of the
architecture's batching wins.  The scheduler turns the concurrent stream into
fused micro-batches:

  - **Admission** is bounded (``BatchPolicy.max_queue_requests``): when the
    queue is full, or a request's budget is already smaller than the current
    service-time estimate, it is shed *at submit time* with a typed error
    (``QueueFullError`` / ``DeadlineError``) instead of queueing work that
    can only miss — bounded-queue backpressure.
  - **Batch close rule**: a forming batch closes on ``max_batch_keys`` /
    ``max_batch_requests``, or when the earliest admitted deadline's slack
    (deadline − now − service-time estimate) runs out, whichever first.
    Requests without deadlines close after ``max_wait_s`` so a lone request
    never waits for co-travellers that may not come.
  - **Version grouping**: only requests pinned to the same explicit version
    (or all unpinned) coalesce into one micro-batch, so a batch pins exactly
    one engine build for its whole lifetime — no micro-batch ever mixes
    versions, even while ``publish``/``publish_delta`` run concurrently.

The service-time estimate is an EWMA of observed batch service times,
reported back by the server after every finish.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.core.engine import QueryResult, TableResult


# ---------------------------------------------------------------------------
# typed shed / admission errors
# ---------------------------------------------------------------------------
class ShedError(RuntimeError):
    """Base class: the server refused or dropped the request by policy."""


class QueueFullError(ShedError):
    """Admission queue at capacity — back off and retry (backpressure)."""


class DeadlineError(ShedError):
    """The latency budget cannot be met (at admission) or has already
    expired (in queue) — serving it would only burn capacity on a result
    the client will discard."""


class ServerClosedError(ShedError):
    """Submitted to a server that is shutting down."""


# ---------------------------------------------------------------------------
# policy + stats
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    max_batch_keys: int = 8192        # fused key budget per micro-batch
    max_batch_requests: int = 64
    max_queue_requests: int = 256     # admission bound (backpressure)
    max_wait_s: float = 2e-3          # close rule for deadline-less traffic
    service_time_init_s: float = 3e-3  # EWMA seed for the slack computation
    service_time_alpha: float = 0.2   # EWMA weight when service gets SLOWER
    service_time_alpha_down: float = 0.5  # weight when it gets faster — a
    # transient stall (cold jit compile, publish burst) must not keep
    # admission shedding long after service recovers
    latency_reservoir: int = 200_000  # completed-request latencies kept


@dataclasses.dataclass
class StatsSnapshot:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    shed_queue_full: int = 0
    shed_deadline: int = 0
    batches: int = 0
    launches: int = 0
    keys_requested: int = 0
    keys_deviceside: int = 0
    deadline_hits: int = 0
    deadline_misses: int = 0
    p50_ms: float = 0.0
    p99_ms: float = 0.0
    mean_occupancy: float = 0.0       # requests per micro-batch
    coalesce_rate: float = 0.0        # keys eliminated before the device
    shed_rate: float = 0.0

    def summary(self) -> str:
        return (f"{self.completed}/{self.submitted} served "
                f"p50={self.p50_ms:.2f}ms p99={self.p99_ms:.2f}ms "
                f"occupancy={self.mean_occupancy:.1f} req/batch "
                f"coalesce={self.coalesce_rate:.0%} "
                f"shed={self.shed_rate:.1%} "
                f"({self.shed_queue_full} queue-full, "
                f"{self.shed_deadline} deadline)")


class ServerStats:
    """Thread-safe counters + latency reservoir behind ``snapshot()``."""

    def __init__(self, policy: BatchPolicy):
        self._lock = threading.Lock()
        self._policy = policy
        self._c = StatsSnapshot()
        self._latencies_s: list[float] = []
        self._lat_next = 0

    def on_submit(self) -> None:
        with self._lock:
            self._c.submitted += 1

    def on_shed(self, kind: str) -> None:
        with self._lock:
            if kind == "queue_full":
                self._c.shed_queue_full += 1
            else:
                self._c.shed_deadline += 1

    def on_batch(self, n_requests: int, keys_requested: int,
                 keys_deviceside: int, launches: int) -> None:
        with self._lock:
            self._c.batches += 1
            self._c.launches += launches
            self._c.keys_requested += keys_requested
            self._c.keys_deviceside += keys_deviceside

    def on_complete(self, latency_s: float,
                    deadline_met: Optional[bool]) -> None:
        with self._lock:
            self._c.completed += 1
            if deadline_met is not None:
                if deadline_met:
                    self._c.deadline_hits += 1
                else:
                    self._c.deadline_misses += 1
            # ring buffer of the most recent latencies: percentiles must
            # track current behavior, not freeze on the first N requests
            if len(self._latencies_s) < self._policy.latency_reservoir:
                self._latencies_s.append(latency_s)
            else:
                self._latencies_s[self._lat_next] = latency_s
                self._lat_next = (self._lat_next + 1) \
                    % self._policy.latency_reservoir

    def on_failure(self, n: int = 1) -> None:
        with self._lock:
            self._c.failed += n

    def snapshot(self) -> StatsSnapshot:
        with self._lock:
            s = dataclasses.replace(self._c)
            lats = np.asarray(self._latencies_s, dtype=np.float64)
        if len(lats):
            s.p50_ms = float(np.percentile(lats, 50) * 1e3)
            s.p99_ms = float(np.percentile(lats, 99) * 1e3)
        if s.batches:
            s.mean_occupancy = s.completed / s.batches
        if s.keys_requested:
            s.coalesce_rate = 1.0 - s.keys_deviceside / s.keys_requested
        shed = s.shed_queue_full + s.shed_deadline
        if s.submitted:
            s.shed_rate = shed / s.submitted
        return s


# ---------------------------------------------------------------------------
# tickets + pending requests
# ---------------------------------------------------------------------------
class Ticket:
    """Client-side handle: blocks on ``result()`` until the micro-batch the
    request rode in finishes (or the request is shed in queue)."""

    def __init__(self, deadline: Optional[float]):
        self._event = threading.Event()
        self._result: Optional[QueryResult] = None
        self._error: Optional[BaseException] = None
        self.deadline = deadline
        self.batch_id: Optional[int] = None
        self.latency_s: Optional[float] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> QueryResult:
        if not self._event.wait(timeout):
            raise TimeoutError("result not ready")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    # server-side faces -------------------------------------------------
    def _complete(self, result: QueryResult, batch_id: int,
                  latency_s: float) -> None:
        self._result = result
        self.batch_id = batch_id
        self.latency_s = latency_s
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._event.set()


@dataclasses.dataclass
class _Pending:
    tables: dict[str, np.ndarray]
    n_keys: int
    t_submit: float
    deadline: Optional[float]         # monotonic; None = no budget
    version: Optional[int]
    strict: bool
    ticket: Ticket

    @property
    def group(self) -> tuple:
        """Requests coalesce only within one (version, strict) group —
        the single-version-per-micro-batch invariant."""
        return (self.version, self.strict)


# ---------------------------------------------------------------------------
# coalesce / scatter-back
# ---------------------------------------------------------------------------
def coalesce(batch: list[_Pending]) -> tuple[dict[str, np.ndarray],
                                             list[dict[str, tuple[int, int]]]]:
    """Fuse per-request key sets into one engine request; returns the fused
    ``{table: keys}`` dict plus, per request, its ``{table: (lo, hi)}``
    spans for scatter-back.  The engine dedups the fused arrays, so overlap
    ACROSS requests is eliminated exactly like overlap within one."""
    parts: dict[str, list[np.ndarray]] = {}
    lens: dict[str, int] = {}
    spans: list[dict[str, tuple[int, int]]] = []
    for req in batch:
        mine: dict[str, tuple[int, int]] = {}
        for name, keys in req.tables.items():
            lo = lens.get(name, 0)
            parts.setdefault(name, []).append(keys)
            lens[name] = lo + len(keys)
            mine[name] = (lo, lens[name])
        spans.append(mine)
    fused = {name: np.concatenate(ps) for name, ps in parts.items()}
    return fused, spans


def scatter(result: QueryResult,
            span: dict[str, tuple[int, int]]) -> QueryResult:
    """Slice one request's rows back out of the fused result (same version
    tag: every request in the batch was answered from the one pinned
    build)."""
    tables: dict[str, TableResult] = {}
    for name, (lo, hi) in span.items():
        tr = result.tables[name]
        tables[name] = TableResult(
            found=tr.found[lo:hi],
            payloads=None if tr.payloads is None else tr.payloads[lo:hi],
            values=None if tr.values is None else tr.values[lo:hi])
    return QueryResult(version=result.version, tables=tables)


# ---------------------------------------------------------------------------
# the micro-batcher
# ---------------------------------------------------------------------------
class MicroBatcher:
    """Bounded admission queue + deadline-aware batch formation.

    ``admit`` is called from client threads; ``next_batch`` from the single
    scheduler thread.  Expired requests are shed (their tickets fail with
    ``DeadlineError``) during formation, never silently dropped."""

    def __init__(self, policy: BatchPolicy, stats: ServerStats):
        self.policy = policy
        self.stats = stats
        self._queue: deque[_Pending] = deque()
        self._cond = threading.Condition()
        self._closed = False
        self._service_time_s = policy.service_time_init_s
        self._last_observe = time.monotonic()

    # ------------------------------------------------------------------
    @property
    def service_time_s(self) -> float:
        return self._service_time_s

    def observe_service_time(self, seconds: float) -> None:
        with self._cond:        # pool workers report concurrently; a lost
            # fast-side update would keep admission shedding after a stall
            a = (self.policy.service_time_alpha_down
                 if seconds < self._service_time_s
                 else self.policy.service_time_alpha)
            self._service_time_s = ((1 - a) * self._service_time_s
                                    + a * seconds)
            self._last_observe = time.monotonic()

    def _estimate(self, now: float) -> float:
        """Admission-time service estimate.  The EWMA only refreshes when
        batches complete, so with EVERY request being shed there would be
        no observations and a stale stall reading would wedge admission
        into permanent shedding; instead the estimate decays toward the
        policy seed (halving every 250 ms of observation silence)."""
        idle = now - self._last_observe
        if idle <= 0.25:
            return self._service_time_s
        # floor at min(seed, ewma): decay pulls a stalled-high estimate
        # back DOWN toward the seed but must never raise an estimate that
        # is already below it (a fast engine's tight-budget traffic would
        # otherwise shed forever after one idle gap)
        floor = min(self.policy.service_time_init_s, self._service_time_s)
        return max(floor, self._service_time_s * 0.5 ** (idle / 0.25 - 1.0))

    def depth(self) -> int:
        with self._cond:
            return len(self._queue)

    # ------------------------------------------------------------------
    def admit(self, req: _Pending) -> None:
        now = time.monotonic()
        with self._cond:
            if self._closed:
                raise ServerClosedError("server is shutting down")
            if len(self._queue) >= self.policy.max_queue_requests:
                self.stats.on_shed("queue_full")
                raise QueueFullError(
                    f"admission queue full "
                    f"({self.policy.max_queue_requests} requests)")
            est = self._estimate(now)
            if req.deadline is not None and req.deadline - now < est:
                self.stats.on_shed("deadline")
                raise DeadlineError(
                    f"budget {max(req.deadline - now, 0) * 1e3:.2f}ms < "
                    f"estimated service time {est * 1e3:.2f}ms")
            self._queue.append(req)
            self._cond.notify()

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def drain(self) -> list[_Pending]:
        """Pop every still-queued request (after close, when no scheduler
        thread exists to serve them) so the caller can fail their tickets
        instead of leaving result() waiters hanging."""
        with self._cond:
            out = list(self._queue)
            self._queue.clear()
            return out

    # ------------------------------------------------------------------
    def _shed_expired(self, now: float) -> None:
        # must hold self._cond
        live: deque[_Pending] = deque()
        for req in self._queue:
            if req.deadline is not None and now > req.deadline:
                self.stats.on_shed("deadline")
                req.ticket._fail(DeadlineError(
                    "deadline expired while queued"))
            else:
                live.append(req)
        self._queue = live

    def _collect(self) -> tuple[list[_Pending], bool]:
        # must hold self._cond; head-of-line request picks the group.
        # ``saturated`` reports that a matching request exists but could
        # not fit — the batch is as full as it can get, so the caller must
        # close it now rather than wait out max_wait_s for riders that can
        # never join
        head = self._queue[0]
        batch, n_keys, saturated = [], 0, False
        for req in self._queue:
            if req.group != head.group:
                continue
            if batch and (n_keys + req.n_keys > self.policy.max_batch_keys
                          or len(batch) >= self.policy.max_batch_requests):
                saturated = True
                break
            batch.append(req)
            n_keys += req.n_keys
        return batch, saturated

    def next_batch(self) -> Optional[list[_Pending]]:
        """Blocks until a micro-batch closes; ``None`` once the batcher is
        closed and drained."""
        with self._cond:
            while True:
                # wait for at least one live request
                while True:
                    self._shed_expired(time.monotonic())
                    if self._queue:
                        break
                    if self._closed:
                        return None
                    self._cond.wait(timeout=0.05)

                t_open = time.monotonic()
                batch: list[_Pending] = []
                while True:
                    batch, saturated = self._collect()
                    n_keys = sum(r.n_keys for r in batch)
                    if (saturated
                            or n_keys >= self.policy.max_batch_keys
                            or len(batch) >= self.policy.max_batch_requests
                            or self._closed):
                        break
                    # earliest deadline across the WHOLE queue, not just
                    # this batch: a different-(version,strict)-group request
                    # behind the head cannot be served until this batch
                    # closes, so its slack must bound the wait too
                    deadlines = [r.deadline for r in self._queue
                                 if r.deadline is not None]
                    close_at = t_open + self.policy.max_wait_s
                    if deadlines:
                        # earliest deadline's slack, net of the service cost
                        close_at = min(close_at,
                                       min(deadlines) - self._service_time_s)
                    now = time.monotonic()
                    if now >= close_at:
                        break
                    self._cond.wait(timeout=min(close_at - now, 0.01))
                    self._shed_expired(time.monotonic())
                    if not self._queue:
                        batch = []
                        break       # everything shed mid-wait — start over
                if not batch:
                    continue
                members = set(map(id, batch))
                self._queue = deque(r for r in self._queue
                                    if id(r) not in members)
                return batch
