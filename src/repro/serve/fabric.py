"""Multi-process serving fabric: router over shard-server processes.

``ClusterSim`` (core/cluster_sim.py) models the paper's fleet as threads in
one process; this module is the graduation to real processes — the
deployment shape Monolith's fault-tolerance story implies (periodic
parameter snapshots + fast replica respawn) over the repo's own storage:

  - **shard-server process** — ``_shard_server_main``: restores a
    ``StoreBackend`` from an on-disk snapshot (``HybridKVStore.load``,
    bitwise) and serves it through a full ``QueryServer`` (QoS lanes,
    micro-batching) behind the framed wire protocol (api/wire.py).  The
    import path is deliberately jax-free, so a replica boots in fractions
    of a second instead of paying the engine's jax import.
  - **replica groups** — each shard runs ``n_replicas`` identical
    processes restored from the same snapshot; queries round-robin across
    the live ones, updates fan to all of them.
  - **router** — partitions each ``QueryRequest``'s keys by the shared
    hash (``hashcore.hash64``), fans sub-queries out pinned to ONE fleet
    version, merges sub-responses, and re-resolves + retries on a version
    NACK — the one-pinned-version-per-batch rule holds across process
    boundaries: no batch is ever answered from mixed versions.
  - **failover + respawn** — a dead replica's in-flight sub-queries fail
    over to a surviving replica of the same group; the health checker
    respawns the dead process from the latest snapshot and replays the
    update log past it, so the rejoined replica serves the current
    version.  In-flight client requests are never lost: they either
    complete from a survivor or fail with a typed ``FabricError``.

Transport is ``multiprocessing.Pipe`` with the spawn start method (fork
would duplicate jax/thread state into children); message payloads are the
pickle-free codec in api/wire.py.
"""
from __future__ import annotations

import dataclasses
import itertools
import multiprocessing
import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeoutError
from typing import Optional, Sequence

import numpy as np

from repro.api import wire
from repro.api.backends import StoreBackend
from repro.api.types import (Consistency, QoSClass, QueryRequest,
                             QueryResponse, UpdateRequest)
from repro.core.hybrid_store import HybridKVStore
from repro.core.query_types import (EmbeddingTable, QueryResult, TableResult,
                                    VersionEvictedError)
from repro.obs.trace import Span, Tracer, new_id

__all__ = ["FabricConfig", "FabricCounts", "FabricError", "FabricMetrics",
           "NoReplicaError", "ReplicaDeadError", "ReplicaHandle", "Router",
           "shard_of_keys"]


class FabricError(RuntimeError):
    """Base class for fabric serving failures (always typed, never a hang:
    a client request either completes or raises one of these)."""


class ReplicaDeadError(FabricError):
    """The shard process died (or its pipe broke) with work outstanding."""


class NoReplicaError(FabricError):
    """A shard's whole replica group is down — nothing left to fail over
    to (the respawner may still bring one back; retry later)."""


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    n_shards: int = 2
    n_replicas: int = 2               # replica group size per shard
    snapshot_root: str = ""           # required: where snapshots live
    health_period_s: float = 0.25     # health-check / respawn cadence
    snapshot_every: int = 8           # updates between periodic snapshots
    call_timeout_s: float = 30.0      # per-RPC budget (query/update/health)
    spawn_timeout_s: float = 60.0     # replica boot-to-ready budget
    respawn: bool = True              # health checker respawns dead replicas
    version_retries: int = 8          # NACK -> re-resolve attempts per query
    server_workers: int = 2           # QueryServer finish workers per shard
    max_wait_s: float = 0.0           # shard-side micro-batch close rule
    trace_sample_rate: float = 0.0    # fraction of queries traced end-to-end

    def __post_init__(self):
        if self.n_shards < 1 or self.n_replicas < 1:
            raise ValueError("n_shards and n_replicas must be >= 1")
        if not 0.0 <= self.trace_sample_rate <= 1.0:
            raise ValueError(f"trace_sample_rate must be in [0, 1], got "
                             f"{self.trace_sample_rate}")
        if not self.snapshot_root:
            raise ValueError("snapshot_root is required (snapshots are the "
                             "respawn substrate, not an optional extra)")


@dataclasses.dataclass
class FabricCounts:
    """The router's counter set — a plain record so ``snapshot()`` can
    hand out consistent copies and the metrics bridge (obs/bridge.py) can
    enumerate the fields."""
    queries: int = 0
    sub_queries: int = 0
    updates: int = 0
    consistent_batches: int = 0       # merged under one version
    mixed_version_averted: int = 0    # merge saw >1 version -> retried
    version_retries: int = 0          # pinned sub-query NACK -> re-resolve
    failovers: int = 0                # sub-query moved to a survivor
    replica_failures: int = 0         # processes observed dead
    respawns: int = 0
    snapshots: int = 0


class FabricMetrics:
    """Thread-safe fabric counters.  The old dataclass was bumped bare
    (``metrics.queries += 1``) from client threads, the health checker,
    and finish workers at once — increments raced and lost.  Writes now go
    through ``inc`` under a lock; reads keep working attribute-style
    (``router.metrics.respawns``) via ``__getattr__``, each one a locked
    point read of the live counts."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._c = FabricCounts()      # guarded-by: _lock (strict)

    def inc(self, field: str, n: int = 1) -> None:
        with self._lock:
            setattr(self._c, field, getattr(self._c, field) + n)

    def snapshot(self) -> FabricCounts:
        with self._lock:
            return dataclasses.replace(self._c)

    def __getattr__(self, name: str):
        if name.startswith("_"):
            raise AttributeError(name)
        with self._lock:
            return getattr(self._c, name)


# the repo-wide mix hash (hashcore's numpy flavour), restated here so the
# fabric stays importable without jax — hashcore pulls jnp at module load,
# which would put the jax import back on every shard-server's boot path.
# test_fabric.py asserts bit-identity against hashcore.hash64_np.
_C1, _C2, _SEED = np.uint32(0x85EBCA6B), np.uint32(0xC2B2AE35), \
    np.uint32(0x9E3779B9)


def _mix32(h: np.ndarray) -> np.ndarray:
    h = h.astype(np.uint32, copy=True)
    h ^= h >> np.uint32(16)
    h *= _C1
    h ^= h >> np.uint32(13)
    h *= _C2
    h ^= h >> np.uint32(16)
    return h


def shard_of_keys(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """Owning shard per key — the same mix hash the tables themselves use
    (and the same routing as ``ClusterSim``), so the partition is stable
    across processes and restarts."""
    keys = np.asarray(keys, dtype=np.uint64).ravel()
    hi = (keys >> np.uint64(32)).astype(np.uint32)
    lo = keys.astype(np.uint32)
    h = _mix32(_mix32(lo ^ _SEED) ^ hi)
    return (h % np.uint32(n_shards)).astype(np.int32)


# ---------------------------------------------------------------------------
# shard-server child process
# ---------------------------------------------------------------------------
def _shard_server_main(conn, shard_id: int, replica_id: int,
                       snapshot_dir: str, options: dict) -> None:
    """Entry point of one shard-server process (spawn target; must stay
    top-level picklable).  Protocol: restore backend from snapshot, send
    the ready frame (request id 0), then serve frames until SHUTDOWN or
    pipe EOF (parent death).  Every request is answered — a response, a
    typed error, or process death the parent's reader detects."""
    from repro.serve.scheduler import BatchPolicy
    from repro.serve.server import QueryServer

    send_lock = threading.Lock()

    def send(kind: int, rid: int, payload: bytes) -> None:
        with send_lock:
            try:
                conn.send_bytes(wire.pack_frame(kind, rid, payload))
            except (OSError, ValueError, BrokenPipeError):
                pass                  # parent gone; recv loop exits on EOF

    try:
        backend = StoreBackend.load_snapshot(snapshot_dir)
    except BaseException as e:  # noqa: BLE001
        send(wire.KIND_ERROR, 0, wire.encode_error(e))
        return
    # sample_rate 0: the shard never ORIGINATES traces, but requests that
    # arrive carrying a trace context (sampled at the router edge) are
    # recorded, and their spans ride back on the wire response
    tracer = Tracer(sample_rate=0.0,
                    proc=f"shard{shard_id}/r{replica_id}")
    server = QueryServer(
        backend,
        BatchPolicy(max_wait_s=float(options.get("max_wait_s", 0.0))),
        workers=int(options.get("server_workers", 2)),
        tracer=tracer)
    pool = ThreadPoolExecutor(max_workers=4,
                              thread_name_prefix=f"reply-s{shard_id}")
    send(wire.KIND_OK, 0, wire.encode_tree(
        {"shard": shard_id, "replica": replica_id,
         "version": backend.latest_version}))

    running = True
    while running:
        try:
            data = conn.recv_bytes()
        except (EOFError, OSError):
            break
        try:
            kind, rid, payload = wire.unpack_frame(data)
        except wire.WireError:
            continue
        if kind == wire.KIND_QUERY:
            try:
                ticket = server.submit(wire.decode_request(payload))
            except BaseException as e:  # noqa: BLE001
                send(wire.KIND_ERROR, rid, wire.encode_error(e))
                continue

            def reply(rid=rid, ticket=ticket):
                try:
                    res = ticket.result(timeout=60.0)
                except BaseException as e:  # noqa: BLE001
                    send(wire.KIND_ERROR, rid, wire.encode_error(e))
                else:
                    send(wire.KIND_RESPONSE, rid, wire.encode_response(res))

            pool.submit(reply)
        elif kind == wire.KIND_UPDATE:
            try:
                version, upserts, deletes = wire.decode_update(payload)
                if upserts or deletes:
                    backend.apply_update(UpdateRequest(
                        version=version, upserts=upserts, deletes=deletes))
                else:
                    # this shard's partition of the fleet delta is empty:
                    # adopt the fleet version anyway (membership/epoch
                    # semantics) or pinned sub-queries here NACK forever
                    backend.bump_version(version)
                send(wire.KIND_OK, rid, wire.encode_tree(
                    {"version": backend.latest_version}))
            except BaseException as e:  # noqa: BLE001
                send(wire.KIND_ERROR, rid, wire.encode_error(e))
        elif kind == wire.KIND_HEALTH:
            send(wire.KIND_OK, rid, wire.encode_tree(
                {"version": backend.latest_version,
                 "tables": backend.table_names}))
        elif kind == wire.KIND_STATS:
            # observability scrape: this replica's stat silos as one tree
            # (serving counters/percentiles + per-table tier counters)
            try:
                send(wire.KIND_OK, rid, wire.encode_stats({
                    "shard": shard_id, "replica": replica_id,
                    "version": backend.latest_version,
                    "server": dataclasses.asdict(server.stats_snapshot()),
                    "tiers": backend.tier_stats()}))
            except BaseException as e:  # noqa: BLE001
                send(wire.KIND_ERROR, rid, wire.encode_error(e))
        elif kind == wire.KIND_SNAPSHOT:
            try:
                target = wire.decode_tree(payload)["dir"]
                v = backend.snapshot_to(target)
                send(wire.KIND_OK, rid,
                     wire.encode_tree({"dir": target, "version": v}))
            except BaseException as e:  # noqa: BLE001
                send(wire.KIND_ERROR, rid, wire.encode_error(e))
        elif kind == wire.KIND_SHUTDOWN:
            send(wire.KIND_OK, rid, wire.encode_tree({}))
            running = False
        else:
            send(wire.KIND_ERROR, rid, wire.encode_error(
                ValueError(f"unknown frame kind {kind}")))
    # drain in-flight replies while the server still serves them, THEN
    # close the server (its close() fails anything the drain left behind)
    pool.shutdown(wait=True)
    server.close(timeout=5.0)
    try:
        conn.close()
    except OSError:                                # pragma: no cover
        pass


# ---------------------------------------------------------------------------
# parent-side replica handle: one process + multiplexed RPC
# ---------------------------------------------------------------------------
class ReplicaHandle:
    """One shard-server process as seen by the router: a pipe, a reader
    thread demultiplexing responses to per-request futures, and a liveness
    flag.  Death (EOF, broken pipe, failed send) fails every pending
    future with ``ReplicaDeadError`` — callers fail over, nothing hangs."""

    def __init__(self, process, conn, shard_id: int, replica_id: int):
        self.process = process
        self.conn = conn
        self.shard_id = shard_id
        self.replica_id = replica_id
        self.alive = True               # guarded-by: _plock
        # strict: _mark_dead clears the dict while failing the futures,
        # so even a point lookup must serialize with the sweep
        self._pending: dict[int, Future] = {}  # guarded-by: _plock (strict)
        self._plock = threading.Lock()
        self._send_lock = threading.Lock()
        self._ids = itertools.count(1)
        # the ready frame arrives as request id 0
        self.ready: Future = Future()
        self._pending[0] = self.ready
        self._reader = threading.Thread(
            target=self._read_loop, daemon=True,
            name=f"fabric-read-s{shard_id}r{replica_id}")
        self._reader.start()

    @classmethod
    def spawn(cls, ctx, shard_id: int, replica_id: int, snapshot_dir: str,
              cfg: FabricConfig) -> "ReplicaHandle":
        """Start a shard-server from a snapshot and wait for its ready
        frame (which proves the snapshot restored and the server is
        accepting)."""
        parent_conn, child_conn = ctx.Pipe()
        options = {"max_wait_s": cfg.max_wait_s,
                   "server_workers": cfg.server_workers}
        process = ctx.Process(
            target=_shard_server_main,
            args=(child_conn, shard_id, replica_id, snapshot_dir, options),
            daemon=True, name=f"fabric-s{shard_id}r{replica_id}")
        process.start()
        child_conn.close()
        handle = cls(process, parent_conn, shard_id, replica_id)
        try:
            kind, payload = handle.ready.result(cfg.spawn_timeout_s)
        except FutureTimeoutError:
            handle.destroy()
            raise FabricError(
                f"shard {shard_id} replica {replica_id} did not become "
                f"ready within {cfg.spawn_timeout_s}s")
        except BaseException:
            handle.destroy()
            raise
        return handle

    # -- RPC -----------------------------------------------------------
    def submit(self, kind: int, payload: bytes) -> Future:
        if not self.alive:
            raise ReplicaDeadError(
                f"shard {self.shard_id} replica {self.replica_id} is dead")
        rid = next(self._ids)
        fut: Future = Future()
        with self._plock:
            if not self.alive:
                raise ReplicaDeadError(
                    f"shard {self.shard_id} replica {self.replica_id} "
                    f"is dead")
            self._pending[rid] = fut
        try:
            with self._send_lock:
                self.conn.send_bytes(wire.pack_frame(kind, rid, payload))
        except (OSError, ValueError, BrokenPipeError):
            self._mark_dead()
            raise ReplicaDeadError(
                f"shard {self.shard_id} replica {self.replica_id} died "
                f"on send")
        return fut

    def call(self, kind: int, payload: bytes,
             timeout: Optional[float] = None) -> tuple[int, bytes]:
        """Round trip; raises the decoded typed error on a KIND_ERROR
        response and ``ReplicaDeadError``/``FabricError`` on death or
        timeout."""
        fut = self.submit(kind, payload)
        try:
            return fut.result(timeout)
        except FutureTimeoutError:
            raise FabricError(
                f"shard {self.shard_id} replica {self.replica_id} did not "
                f"answer within {timeout}s")

    # -- lifecycle -----------------------------------------------------
    def _read_loop(self) -> None:
        try:
            while True:
                data = self.conn.recv_bytes()
                kind, rid, payload = wire.unpack_frame(data)
                with self._plock:
                    fut = self._pending.pop(rid, None)
                if fut is None:
                    continue
                if kind == wire.KIND_ERROR:
                    fut.set_exception(wire.decode_error(payload))
                else:
                    fut.set_result((kind, bytes(payload)))
        except (EOFError, OSError, wire.WireError):
            pass
        finally:
            self._mark_dead()

    def _mark_dead(self) -> None:
        with self._plock:
            self.alive = False
            pending = list(self._pending.values())
            self._pending.clear()
        for fut in pending:
            if not fut.done():
                fut.set_exception(ReplicaDeadError(
                    f"shard {self.shard_id} replica {self.replica_id} died "
                    f"with the request in flight"))

    def kill(self) -> None:
        """Hard-kill the process (the failure-injection face tests use)."""
        self.process.terminate()

    def shutdown(self, timeout: float = 5.0) -> None:
        """Graceful stop: SHUTDOWN frame, join, then escalate."""
        if self.alive:
            try:
                self.call(wire.KIND_SHUTDOWN, wire.encode_tree({}),
                          timeout=timeout)
            except (FabricError, ReplicaDeadError):
                pass
        self.destroy(join_timeout=timeout)

    def destroy(self, join_timeout: float = 5.0) -> None:
        self.process.join(join_timeout)
        if self.process.is_alive():
            self.process.terminate()
            self.process.join(1.0)
        self._mark_dead()
        try:
            self.conn.close()
        except OSError:                            # pragma: no cover
            pass


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------
class Router:
    """Fan-out / merge / failover over shard replica groups.

    Build one with ``Router.build(embeddings, cfg)`` — it partitions the
    tables by key hash, snapshots each shard's ``StoreBackend`` to disk,
    and spawns ``n_shards * n_replicas`` shard-server processes from those
    snapshots (the same path a respawn takes: bootstrap IS restore).

    The consistency contract mirrors ``StoreBackend`` fleet-wide: the
    fleet retains one version; every sub-query is pinned strict to the
    fleet version resolved at dispatch, so a racing fleet update NACKs
    the sub-query (typed ``VersionEvictedError``) and the router
    re-resolves + retries — a merged response is always single-version.
    """

    def __init__(self, cfg: FabricConfig, table_names: Sequence[str],
                 snapshots: Sequence[tuple[str, int]], version: int):
        self.cfg = cfg
        self._table_names = sorted(table_names)
        self._ctx = multiprocessing.get_context("spawn")
        # non-strict: query_ex pins the version with one benign racy
        # read (an update landing mid-read just means the batch pins
        # the pre-update version, which stays servable)
        self._fleet_version = int(version)  # guarded-by: _update_lock
        # (dir, version) of each shard's latest snapshot — the respawn
        # substrate; updated by snapshot_now()
        # guarded-by: _update_lock (strict)
        self._snapshots: list[tuple[str, int]] = list(snapshots)
        # update log PAST the snapshots: (version, per-shard payloads);
        # a respawned replica restores the snapshot then replays these
        # guarded-by: _update_lock (strict)
        self._update_log: list[tuple[int, list[bytes]]] = []
        self._updates_since_snapshot = 0  # guarded-by: _update_lock (strict)
        # serializes updates, snapshots, and respawn catch-up: a replica
        # must never join mid-update or replay a half-logged delta
        self._update_lock = threading.RLock()
        self.metrics = FabricMetrics()
        # edge tracer: query_ex samples here, shard children record under
        # the propagated context, and the merged cross-process timeline
        # lands back in this tracer (and on the response)
        self.tracer = Tracer(sample_rate=cfg.trace_sample_rate,
                             proc="router")
        self._rr = [itertools.count() for _ in range(cfg.n_shards)]
        # non-strict: the query fan-out reads handles lock-free; a
        # respawn swapping a handle mid-read at worst routes one call
        # to the dying replica, which fails typed and is retried
        self.replicas: list[list[Optional[ReplicaHandle]]] = []  # guarded-by: _update_lock
        try:
            for s in range(cfg.n_shards):
                group = [ReplicaHandle.spawn(self._ctx, s, r,
                                             self._snapshots[s][0], cfg)
                         for r in range(cfg.n_replicas)]
                self.replicas.append(group)
        except BaseException:
            self.close()
            raise
        self._health_stop = threading.Event()
        # serializes health-checker start/stop (same check-then-act
        # race class as QueryServer.start: two concurrent starts used
        # to be able to spawn two health loops)
        self._health_lock = threading.Lock()
        # guarded-by: _health_lock (strict)
        self._health_thread: Optional[threading.Thread] = None
        self._closed = False
        if cfg.respawn:
            self.start_health_checker()

    # -- construction ---------------------------------------------------
    @classmethod
    def build(cls, embeddings: Sequence[EmbeddingTable],
              cfg: FabricConfig, *, version: int = 1) -> "Router":
        """Partition + snapshot + spawn.  Each table's keys are routed by
        ``shard_of_keys``; each shard's partition becomes a
        ``HybridKVStore`` inside a ``StoreBackend`` snapshotted to
        ``<snapshot_root>/shard<k>/v<version>`` — then the builder stores
        are closed and every replica boots from disk, proving at
        construction time the restore path a failure will later rely on."""
        if not embeddings:
            raise ValueError("need at least one table")
        names = [t.name for t in embeddings]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate table names in {names}")
        os.makedirs(cfg.snapshot_root, exist_ok=True)
        owners = {t.name: shard_of_keys(t.keys, cfg.n_shards)
                  for t in embeddings}
        snapshots = []
        for s in range(cfg.n_shards):
            stores = {}
            for t in embeddings:
                mask = owners[t.name] == s
                if not mask.any():
                    raise ValueError(
                        f"table {t.name!r} routed no keys to shard {s}; "
                        f"use fewer shards or more keys")
                keys = np.asarray(t.keys, dtype=np.uint64)[mask]
                values = np.asarray(t.values)[mask]
                stores[t.name] = HybridKVStore(
                    keys, values, hot_fraction=t.hot_fraction,
                    variant=t.variant)
            backend = StoreBackend(stores, version=version)
            path = os.path.join(cfg.snapshot_root, f"shard{s}",
                                f"v{version}")
            os.makedirs(os.path.dirname(path), exist_ok=True)
            backend.snapshot_to(path)
            for store in stores.values():
                store.close()
            snapshots.append((path, version))
        return cls(cfg, names, snapshots, version)

    # -- protocol faces --------------------------------------------------
    @property
    def fleet_version(self) -> int:
        return self._fleet_version

    @property
    def table_names(self) -> list[str]:
        return list(self._table_names)

    def query(self, request: QueryRequest) -> QueryResponse:
        return self.query_ex(request)[0]

    def query_ex(self, request: QueryRequest
                 ) -> tuple[QueryResponse, dict]:
        """Fan out one request, merge one single-version response; returns
        ``(response, {"keys_deviceside", "launches"})`` for the backend's
        coalesce stats.  Raises only typed errors: consistency NACKs
        (``VersionEvictedError``/``ConsistencyError``), shard-side shed
        errors, or ``FabricError`` when retries/replicas are exhausted."""
        if self._closed:
            raise FabricError("router is closed")
        t0 = time.monotonic()
        # dedup + partition once; the retry loop redispatches the same
        # sub-requests under a re-resolved version
        parts = {}                    # name -> (uniq, inverse, owners)
        sub_tables: dict[int, dict[str, np.ndarray]] = {}
        deviceside = 0
        for name, keys in request.tables.items():
            uniq, inverse = np.unique(keys, return_inverse=True)
            owner = shard_of_keys(uniq, self.cfg.n_shards)
            parts[name] = (uniq, inverse, owner)
            deviceside += len(uniq)
            for s in np.unique(owner):
                sub_tables.setdefault(int(s), {})[name] = uniq[owner == s]
        info = {"keys_deviceside": deviceside, "launches": len(sub_tables)}
        self.metrics.inc("queries")

        # edge sampling: an incoming context propagates; otherwise the
        # router's tracer decides.  Sub-queries carry the context with the
        # route span as parent, so shard-side timelines merge under it.
        tctx = request.trace
        if tctx is None:
            tid = self.tracer.sample()
            if tid is not None:
                tctx = {"trace_id": tid}
        route_sid = new_id() if tctx is not None else None
        sub_trace = None if tctx is None else \
            {"trace_id": tctx["trace_id"], "parent_id": route_sid}

        last_error: Optional[BaseException] = None
        for attempt in range(self.cfg.version_retries):
            if attempt:
                self.metrics.inc("version_retries")
                time.sleep(0.001 * attempt)       # let the update settle
            v = self._fleet_version
            if request.consistency.mode == "pinned" \
                    and request.consistency.version != v:
                raise VersionEvictedError(
                    f"version {request.consistency.version} not retained; "
                    f"the fleet serves only [{v}]")
            try:
                responses, rpc_spans = self._fan_out(
                    sub_tables, v, request.qos, trace=sub_trace)
            except VersionEvictedError as e:
                last_error = e        # stale pin: re-resolve and retry
                continue
            versions = {r.version for r in responses.values()}
            if len(versions) > 1:                  # pragma: no cover
                # strict pins make this unreachable; belt + braces so a
                # future bug turns into a retry, never a mixed answer
                self.metrics.inc("mixed_version_averted")
                last_error = FabricError(
                    f"sub-responses spanned versions {sorted(versions)}")
                continue
            served = versions.pop() if versions else v
            request.consistency.check(served)     # min_version post-check
            self.metrics.inc("consistent_batches")
            merged = self._merge(parts, responses, served)
            trace_wire = None
            if tctx is not None:
                trace_wire = self._merge_trace(
                    tctx, route_sid, t0, rpc_spans, responses, served,
                    attempt)
            return (QueryResponse.from_result(
                merged, qos=request.qos,
                latency_s=time.monotonic() - t0,
                trace=trace_wire), info)
        raise FabricError(
            f"query failed after {self.cfg.version_retries} attempts"
            ) from last_error

    def _merge_trace(self, tctx: dict, route_sid: str, t0: float,
                     rpc_spans: list, responses: dict, version: int,
                     attempt: int) -> list:
        """One cross-process timeline: the router's ``route`` root + its
        per-shard ``shard_rpc`` spans + every span the shard servers
        recorded (admission ... scatter, stamped on the shared
        CLOCK_MONOTONIC timebase).  Recorded in the router tracer and
        returned as wire dicts on the response."""
        tid = tctx["trace_id"]
        spans = [Span(tid, "route", t0, time.monotonic(),
                      parent_id=tctx.get("parent_id"), span_id=route_sid,
                      proc=self.tracer.proc,
                      tags={"version": version, "attempts": attempt + 1,
                            "shards": sorted(responses)})]
        spans.extend(rpc_spans)
        for res in responses.values():
            if res.trace:
                spans.extend(Span.from_wire(d) for d in res.trace)
        self.tracer.record(spans)
        return [s.to_wire() for s in spans]

    def _fan_out(self, sub_tables: dict, version: int, qos: QoSClass,
                 trace: Optional[dict] = None) -> tuple[dict, list]:
        """Dispatch every shard's sub-query pinned strict to ``version``,
        with per-shard failover to surviving replicas; returns
        ``({shard: QueryResult}, [shard_rpc Span, ...])`` (the span list
        is empty for untraced queries)."""
        payloads = {}
        for s, tables in sub_tables.items():
            payloads[s] = wire.encode_request(QueryRequest(
                tables=tables, qos=qos,
                consistency=Consistency.pinned(version),
                trace=trace))
        t_dispatch = time.monotonic()
        futures = {}
        for s, payload in payloads.items():
            futures[s] = self._submit_shard(s, payload)
            self.metrics.inc("sub_queries")
        responses = {}
        rpc_spans: list = []
        first_error: Optional[BaseException] = None
        for s, fut in futures.items():
            payload = payloads[s]
            while True:
                try:
                    _, data = fut.result(self.cfg.call_timeout_s)
                    responses[s] = wire.decode_response(data)
                    if trace is not None:
                        rpc_spans.append(Span(
                            trace["trace_id"], "shard_rpc", t_dispatch,
                            time.monotonic(),
                            parent_id=trace.get("parent_id"),
                            proc=self.tracer.proc, tags={"shard": s}))
                    break
                except FutureTimeoutError:
                    first_error = first_error or FabricError(
                        f"shard {s} did not answer within "
                        f"{self.cfg.call_timeout_s}s")
                    break
                except ReplicaDeadError:
                    # the replica died mid-flight: the request is NOT
                    # lost — re-dispatch the identical pinned sub-query
                    # to a survivor (NoReplicaError if none remain)
                    self.metrics.inc("failovers")
                    try:
                        fut = self._submit_shard(s, payload)
                        self.metrics.inc("sub_queries")
                    except NoReplicaError as e:
                        first_error = first_error or e
                        break
                except VersionEvictedError:
                    raise                  # caller re-resolves + retries
                except BaseException as e:  # noqa: BLE001
                    first_error = first_error or e
                    break
        if first_error is not None:
            raise first_error
        return responses, rpc_spans

    def _submit_shard(self, shard: int, payload: bytes) -> Future:
        group = self.replicas[shard]
        for _ in range(len(group)):
            handle = group[next(self._rr[shard]) % len(group)]
            if handle is None or not handle.alive:
                continue
            try:
                return handle.submit(wire.KIND_QUERY, payload)
            except ReplicaDeadError:
                self.metrics.inc("replica_failures")
                continue
        raise NoReplicaError(f"shard {shard} has no live replica")

    def _merge(self, parts: dict, responses: dict,
               version: int) -> QueryResult:
        """Stitch per-shard unique-key results back to request order."""
        tables = {}
        for name, (uniq, inverse, owner) in parts.items():
            found_u = np.zeros(len(uniq), dtype=bool)
            values_u: Optional[np.ndarray] = None
            for s, res in responses.items():
                if name not in res.tables:
                    continue
                tr = res.tables[name]
                pos = np.flatnonzero(owner == s)
                found_u[pos] = tr.found
                if tr.values is not None:
                    if values_u is None:
                        values_u = np.zeros(
                            (len(uniq), tr.values.shape[1]), dtype=np.uint8)
                    values_u[pos] = tr.values
            if values_u is None:
                values_u = np.zeros((len(uniq), 0), dtype=np.uint8)
            tables[name] = TableResult(found=found_u[inverse],
                                       values=values_u[inverse])
        return QueryResult(version=version, tables=tables)

    # -- updates ---------------------------------------------------------
    def apply_update(self, update: UpdateRequest) -> None:
        """Partition a fleet delta by shard and fan it to EVERY live
        replica; the fleet version advances once all live replicas acked
        (dead ones catch up from the log at respawn).  Shards whose
        partition is empty get a bare version bump — every shard serves
        the new fleet version, or pinned sub-queries would NACK forever."""
        if not update.is_delta:
            raise ValueError("the fabric's stores mutate in place; only "
                             "delta updates (upserts/deletes) apply")
        for name in set(update.upserts) | set(update.deletes):
            if name not in self._table_names:
                raise KeyError(f"unknown table {name!r}; fleet serves "
                               f"{self._table_names}")
        with self._update_lock:
            if update.version <= self._fleet_version:
                raise ValueError(
                    f"update version {update.version} must exceed the "
                    f"fleet version {self._fleet_version}")
            payloads = self._partition_update(update)
            # log BEFORE sending: a replica that dies mid-send respawns
            # from snapshot + log and must find this delta there
            self._update_log.append((update.version, payloads))
            acks = []
            for s, group in enumerate(self.replicas):
                for handle in group:
                    if handle is None or not handle.alive:
                        continue
                    try:
                        acks.append(
                            (s, handle,
                             handle.submit(wire.KIND_UPDATE, payloads[s])))
                    except ReplicaDeadError:
                        self.metrics.inc("replica_failures")
            acked_shards = set()
            for s, handle, fut in acks:
                try:
                    fut.result(self.cfg.call_timeout_s)
                    acked_shards.add(s)
                except (ReplicaDeadError, FutureTimeoutError):
                    self.metrics.inc("replica_failures")
                # a typed application error (bad rows) re-raises: the
                # update was validated identically everywhere, so one
                # replica failing it means they all would
            if acked_shards != set(range(self.cfg.n_shards)):
                missing = sorted(set(range(self.cfg.n_shards))
                                 - acked_shards)
                raise FabricError(
                    f"update {update.version} not acked by any replica of "
                    f"shards {missing}; fleet version stays "
                    f"{self._fleet_version}")
            self._fleet_version = update.version
            self.metrics.inc("updates")
            self._updates_since_snapshot += 1
            due = self._updates_since_snapshot >= self.cfg.snapshot_every
        if due:
            self.snapshot_now()

    def _partition_update(self, update: UpdateRequest) -> list[bytes]:
        per_up: list[dict] = [{} for _ in range(self.cfg.n_shards)]
        per_del: list[dict] = [{} for _ in range(self.cfg.n_shards)]
        for name, (keys, rows) in update.upserts.items():
            keys = np.asarray(keys, dtype=np.uint64).ravel()
            rows = np.asarray(rows)
            owner = shard_of_keys(keys, self.cfg.n_shards)
            for s in np.unique(owner):
                mask = owner == s
                per_up[int(s)][name] = (keys[mask], rows[mask])
        for name, keys in update.deletes.items():
            keys = np.asarray(keys, dtype=np.uint64).ravel()
            owner = shard_of_keys(keys, self.cfg.n_shards)
            for s in np.unique(owner):
                per_del[int(s)][name] = keys[owner == s]
        return [wire.encode_update(update.version, per_up[s], per_del[s])
                for s in range(self.cfg.n_shards)]

    # -- snapshots + respawn ---------------------------------------------
    def snapshot_now(self) -> None:
        """Ask one live replica per shard to snapshot, record the new
        generation, truncate the replayed log, and drop the superseded
        snapshot dirs."""
        import shutil
        with self._update_lock:
            v = self._fleet_version
            old = []
            for s in range(self.cfg.n_shards):
                path = os.path.join(self.cfg.snapshot_root, f"shard{s}",
                                    f"v{v}")
                handle = self._any_alive(s)
                if handle is None:
                    continue          # shard fully down; keep old snapshot
                try:
                    handle.call(wire.KIND_SNAPSHOT,
                                wire.encode_tree({"dir": path}),
                                timeout=self.cfg.call_timeout_s)
                except (FabricError, ReplicaDeadError):
                    continue
                if self._snapshots[s][0] != path:
                    old.append(self._snapshots[s][0])
                self._snapshots[s] = (path, v)
            floor = min(sv for _, sv in self._snapshots)
            self._update_log = [e for e in self._update_log if e[0] > floor]
            self._updates_since_snapshot = 0
            self.metrics.inc("snapshots")
        for path in old:
            shutil.rmtree(path, ignore_errors=True)

    def _any_alive(self, shard: int) -> Optional[ReplicaHandle]:
        for handle in self.replicas[shard]:
            if handle is not None and handle.alive:
                return handle
        return None

    # -- observability ----------------------------------------------------
    def collect_shard_stats(self, timeout_s: float = 5.0) -> dict:
        """Scrape every live replica's stat silos over KIND_STATS:
        ``{"shard<k>/r<j>": {"server": ..., "tiers": ..., ...}}``.  Dead
        or unresponsive replicas are simply absent — a scrape must degrade,
        never raise, mid-failover (the metrics endpoint calls this)."""
        out: dict[str, dict] = {}
        ping = wire.encode_stats({})
        for s, group in enumerate(self.replicas):
            for r, handle in enumerate(group):
                if handle is None or not handle.alive:
                    continue
                try:
                    _, data = handle.call(wire.KIND_STATS, ping,
                                          timeout=timeout_s)
                    out[f"shard{s}/r{r}"] = wire.decode_stats(data)
                except (FabricError, ReplicaDeadError):
                    continue
        return out

    def respawn(self, shard: int, replica: int) -> None:
        """Bring a dead replica back: boot from the shard's latest
        snapshot, replay the update log past it (all under the update
        lock, so no fleet delta lands mid-catch-up), then swap the handle
        live.  The health checker calls this; tests may too."""
        with self._update_lock:
            old = self.replicas[shard][replica]
            if old is not None and old.alive:
                return
            if old is not None:
                old.destroy(join_timeout=1.0)
            snap_dir, snap_v = self._snapshots[shard]
            handle = ReplicaHandle.spawn(self._ctx, shard, replica,
                                         snap_dir, self.cfg)
            try:
                for v, payloads in self._update_log:
                    if v <= snap_v:
                        continue
                    handle.call(wire.KIND_UPDATE, payloads[shard],
                                timeout=self.cfg.call_timeout_s)
            except BaseException:
                handle.destroy()
                raise
            self.replicas[shard][replica] = handle
            self.metrics.inc("respawns")

    # -- health ----------------------------------------------------------
    def start_health_checker(self) -> None:
        with self._health_lock:
            if self._health_thread is not None:
                return
            self._health_stop.clear()
            self._health_thread = threading.Thread(
                target=self._health_loop, daemon=True, name="fabric-health")
            self._health_thread.start()

    def stop_health_checker(self) -> None:
        # join under the lock: the loop never takes _health_lock (respawn
        # uses _update_lock), and holding it through the join means a
        # concurrent start cannot interleave with a half-stopped loop and
        # resurrect the Event mid-shutdown
        with self._health_lock:
            if self._health_thread is None:
                return
            self._health_stop.set()
            self._health_thread.join()
            self._health_thread = None

    def _health_loop(self) -> None:
        ping = wire.encode_tree({})
        while not self._health_stop.wait(self.cfg.health_period_s):
            for s, group in enumerate(self.replicas):
                for r, handle in enumerate(group):
                    if self._health_stop.is_set():
                        return
                    if handle is None or not handle.alive:
                        self.metrics.inc("replica_failures")
                        if self.cfg.respawn:
                            try:
                                self.respawn(s, r)
                            except BaseException:  # noqa: BLE001
                                pass   # next tick retries
                        continue
                    try:
                        handle.call(wire.KIND_HEALTH, ping,
                                    timeout=self.cfg.call_timeout_s)
                    except (FabricError, ReplicaDeadError):
                        pass           # reader marked it; next tick respawns

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        self._closed = True
        if getattr(self, "_health_thread", None) is not None:
            self.stop_health_checker()
        for group in getattr(self, "replicas", []):
            for handle in group:
                if handle is not None:
                    handle.shutdown()

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
