"""QueryServer — concurrent batch-query serving over any BatchQueryBackend.

The paper's headline is answering batch queries "within milliseconds" under
heavy concurrent traffic; a backend (api/backends.py — the fused
MultiTableEngine, standalone HybridKVStore tables, or a ClusterSim replica
fleet) supplies the version-pinned split-phase query, and this module
supplies the serving layer in front of it:

  - many concurrent clients submit typed ``QueryRequest``s (per-table key
    sets + QoS class + consistency + optional latency budget);
  - the scheduler (serve/scheduler.py) runs one admission lane per QoS
    class — weighted service, class-aware shedding (PREFETCH before
    RANKING), per-class ``BatchPolicy`` overrides — and coalesces each
    lane's stream into deadline-aware micro-batches;
  - each micro-batch pins exactly one backend version for its whole
    lifetime (``backend.begin`` resolves the build once), so concurrent
    ``publish``/``publish_delta`` calls can never produce a mixed-version
    batch, in any lane;
  - launch/finish are double-buffered: the single scheduler thread stages +
    launches batch i+1 while the worker pool blocks on batch i's results
    and scatters ``QueryResponse`` slices back to each request's ticket.

Example::

    server = QueryServer(engine, BatchPolicy(max_batch_keys=4096))
    client = FeatureClient(server)
    res = client.query({"item_attr": ids}, qos="RANKING", budget_s=0.050)
    print(server.stats_snapshot().summary())     # totals + per-class
    server.close()

``submit`` takes a ``QueryRequest`` only; callers go through
``FeatureClient`` (the PR-3 raw-dict shim served its one release and is
gone).  Shedding surfaces as typed errors (``QueueFullError``,
``DeadlineError``) from ``submit``/``Ticket.result``.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

from repro.api.backends import as_backend
from repro.api.types import (ConsistencyError, QueryRequest, QueryResponse)
from repro.obs.trace import Span, Tracer
from repro.serve.scheduler import (BatchPolicy, MicroBatcher, ServerStats,
                                   ServerClosedError, StatsSnapshot, Ticket,
                                   _Pending, coalesce, scatter)


class QueryServer:
    """Admission + QoS-laned micro-batching + double-buffered execution in
    front of a ``BatchQueryBackend``.  Thread-safe: ``submit``/``query``
    may be called from any number of client threads; updates
    (``publish``/``publish_delta``/``apply_update``) may run concurrently
    from an updater thread."""

    def __init__(self, backend, policy: Optional[BatchPolicy] = None, *,
                 class_policies: Optional[dict] = None,
                 lane_weights: Optional[dict] = None,
                 workers: int = 2, pipeline_depth: int = 2,
                 tracer: Optional[Tracer] = None,
                 start: bool = True):
        if pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        if workers < 1:
            raise ValueError("workers must be >= 1")
        # optional request tracing (obs/trace.py): with no tracer the only
        # per-request cost is `is None` checks; with one, the tracer's
        # sample() decides which fresh requests get a span timeline, and
        # requests arriving with a trace context are always recorded
        self.tracer = tracer
        self.backend = as_backend(backend)
        # legacy face: engine-backed servers keep their .engine attribute
        self.engine = getattr(self.backend, "engine", None)
        self.policy = policy or BatchPolicy()
        self.stats = ServerStats(self.policy)
        # MicroBatcher validates class_policies / lane_weights (unknown QoS
        # names, non-BatchPolicy overrides, non-positive weights all raise
        # ValueError at construction)
        self._batcher = MicroBatcher(self.policy, self.stats,
                                     class_policies=class_policies,
                                     lane_weights=lane_weights)
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="qs-finish")
        # bounds batches between launch and finish: depth 2 is the classic
        # double buffer (one in flight on device, one being finished)
        self._inflight = threading.BoundedSemaphore(pipeline_depth)
        # batches between launch and ticket settlement, keyed by batch id
        # (one dict op per batch — this sits on the serial launch path):
        # close() waits these out under its timeout, then fails whatever
        # remains — a caller blocked in result() must never hang on a
        # server that shut down
        # plain dict, no lock: batch-id keyed stores/pops are atomic
        # under the GIL, and close()'s sweep tolerates racing pops (ticket
        # settlement is first-write-wins) — the launch path stays free of
        # lock traffic
        self._inflight_reqs: dict[int, list] = {}
        self._batch_ids = itertools.count()
        # serializes start()/close() thread management: an unguarded
        # check-then-act in start() let two concurrent callers each see
        # _scheduler=None and spawn two scheduler threads draining the
        # same lanes
        self._lifecycle_lock = threading.Lock()
        # guarded-by: _lifecycle_lock
        self._scheduler: Optional[threading.Thread] = None
        self._closed = False
        if start:
            self.start()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> None:
        with self._lifecycle_lock:
            if self._scheduler is not None:
                return
            self._scheduler = threading.Thread(
                target=self._run, name="qs-scheduler", daemon=True)
            self._scheduler.start()

    def close(self, timeout: float = 10.0) -> None:
        """Stop admitting, drain every lane, join the pipeline — all under
        one ``timeout`` budget.  Three places a request can be stranded,
        all handled:

          - queued but never batched (any lane): drained here and failed
            with ``ServerClosedError``;
          - launched but not finished: waited out under the remaining
            budget, then failed with ``ServerClosedError`` if the pool is
            wedged (settlement is first-write-wins, so a late finish that
            does land is simply ignored);
          - scheduler never started / join timed out: same drain + fail.

        No caller blocked in ``Ticket.result()`` is ever left hanging."""
        deadline = time.monotonic() + timeout
        self._closed = True
        self._batcher.close()
        # detach the thread handle under the lock, join outside it: a
        # concurrent start() must not block on our (bounded but long)
        # join, and a post-close start() spawns a scheduler that exits
        # immediately against the closed batcher
        with self._lifecycle_lock:
            scheduler, self._scheduler = self._scheduler, None
        if scheduler is not None:
            scheduler.join(max(deadline - time.monotonic(), 0.0))
        for req in self._batcher.drain():
            self.stats.on_failure(1, req.qos)
            req.ticket._fail(ServerClosedError("server closed before the "
                                               "request was served"))
        # the former shutdown(wait=True) ignored the timeout outright: a
        # backend wedged in finish() hung close() — and the caller —
        # forever.  Wait without blocking, bounded by what is left of the
        # budget, then fail the stragglers.
        self._pool.shutdown(wait=False)
        while self._inflight_reqs and time.monotonic() < deadline:
            time.sleep(0.002)
        leftovers = []
        while True:
            try:
                leftovers.extend(self._inflight_reqs.popitem()[1])
            except KeyError:
                break
        for req in leftovers:
            # first-write-wins: only count the failure if close actually
            # settled the ticket (a finish worker may have just beaten us)
            if req.ticket._fail(ServerClosedError(
                    "server close timed out with the request in flight")):
                self.stats.on_failure(1, req.qos)

    def __enter__(self) -> "QueryServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # client faces
    # ------------------------------------------------------------------
    def submit(self, request: QueryRequest) -> Ticket:
        """Enqueue one request and return its ticket.

        Takes a ``QueryRequest`` alone — QoS, consistency, and budget
        travel inside it; callers build one through ``FeatureClient``.
        (The PR-3 raw-dict + ``version=``/``strict=`` shim is gone.)

        Raises ``QueueFullError`` / ``DeadlineError`` / ``ServerClosedError``
        at admission time when the request is shed by policy."""
        if self._closed:
            raise ServerClosedError("server is closed")
        if not isinstance(request, QueryRequest):
            raise TypeError(
                "QueryServer.submit takes a QueryRequest; raw "
                "{table: keys} dicts go through FeatureClient.query/submit")
        req = request
        pin_version, pin_strict = req.consistency.pin_args()
        tracer = self.tracer
        tctx = None
        if tracer is not None:
            if req.trace is not None:
                tctx = dict(req.trace)   # propagated edge decision
            else:
                tid = tracer.sample()    # rate 0 short-circuits
                if tid is not None:
                    tctx = {"trace_id": tid}
        now = time.monotonic()
        deadline = None if req.budget_s is None else now + req.budget_s
        ticket = Ticket(deadline)
        pending = _Pending(
            tables=req.tables, n_keys=req.n_keys, t_submit=now,
            deadline=deadline, version=pin_version, strict=pin_strict,
            qos=req.qos, consistency=req.consistency, ticket=ticket,
            trace=tctx)
        self.stats.on_submit(req.qos)
        try:
            self._batcher.admit(pending)   # raises the typed shed errors
        except ServerClosedError:
            # keep the snapshot reconcilable (submitted == completed +
            # failed + shed): a close() racing this submit is a failure,
            # not a silently vanished request
            self.stats.on_failure(1, req.qos)
            raise
        if tctx is not None:
            # stamped post-admit; the scheduler may already be batching
            # this request, so span emission falls back to t_submit when
            # it wins that race
            tctx["t_admit"] = time.monotonic()
        return ticket

    def query(self, request: QueryRequest, *,
              timeout: Optional[float] = None) -> QueryResponse:
        """Synchronous convenience: submit + wait.  Exceptions that failed
        the micro-batch (e.g. ``VersionEvictedError`` under a pinned
        consistency) or shed the request re-raise here."""
        return self.submit(request).result(timeout)

    def apply_update(self, update) -> None:
        """Publish through the backend while serving continues (micro-
        batches pin their build at begin time, so this never mixes
        versions into an in-flight batch)."""
        self.backend.apply_update(update)

    def stats_snapshot(self) -> StatsSnapshot:
        return self.stats.snapshot()

    def reset_stats(self) -> None:
        """Fresh counters/latencies — start a measurement window after
        warmup (cold jit compiles otherwise dominate the percentiles)."""
        self.stats = ServerStats(self.policy)
        self._batcher.stats = self.stats

    @property
    def queue_depth(self) -> int:
        return self._batcher.depth()

    @property
    def lane_depths(self) -> dict[str, int]:
        return self._batcher.lane_depths()

    # ------------------------------------------------------------------
    # runtime retuning (traffic/controller.py closes the loop here)
    # ------------------------------------------------------------------
    def lane_policies(self) -> dict[str, BatchPolicy]:
        """The live per-lane close rules (post any runtime retunes)."""
        return self._batcher.lane_policies()

    def retune_lane(self, qos, **changes) -> BatchPolicy:
        """Retune one lane's close rules while serving.

        ``changes`` may touch only the lane-scoped fields
        (``max_batch_keys``, ``max_batch_requests``, ``max_wait_s``);
        the new policy is rebuilt through ``BatchPolicy`` so its
        ``__post_init__`` validation is the oracle — a bad knob raises
        here and the lane keeps its old policy.  Single-writer by
        design (one controller per server); returns the applied policy."""
        lane_fields = {"max_batch_keys", "max_batch_requests", "max_wait_s"}
        unknown = set(changes) - lane_fields
        if unknown:
            raise ValueError(f"retune_lane can only change "
                             f"{sorted(lane_fields)}, got {sorted(unknown)}")
        current = self._batcher.lane_policy(qos)
        new = dataclasses.replace(current, **changes)
        self._batcher.set_lane_policy(qos, new)
        return new

    # ------------------------------------------------------------------
    # scheduler pipeline
    # ------------------------------------------------------------------
    def _run(self) -> None:
        while True:
            batch = self._batcher.next_batch()
            if batch is None:
                return
            self._inflight.acquire()
            batch_id = next(self._batch_ids)
            # batch-level trace timestamps, shared by every traced rider
            # (coalesce/pin/begin/device/finish happen once per batch)
            tinfo = None
            if self.tracer is not None \
                    and any(r.trace is not None for r in batch):
                tinfo = {"formed": time.monotonic()}
            fused, spans = coalesce(batch)
            if tinfo is not None:
                tinfo["coalesced"] = time.monotonic()
            t_launch = time.monotonic()
            # in-flight BEFORE begin: a request stalled inside a slow
            # backend.begin() must be visible to close()'s drain, or a
            # bounded close times out believing nothing is outstanding and
            # strands the ticket
            self._inflight_reqs[batch_id] = batch
            try:
                # begin pins ONE version for the whole micro-batch; the
                # build reference keeps that version's tables alive even if
                # a concurrent publish evicts it from the window mid-flight
                inflight = self.backend.begin(
                    fused, version=batch[0].version, strict=batch[0].strict)
                if tinfo is not None:
                    tinfo["begun"] = time.monotonic()
            except BaseException as e:  # noqa: BLE001
                self._inflight.release()
                self._inflight_reqs.pop(batch_id, None)
                if len(batch) == 1:
                    self.stats.on_failure(1, batch[0].qos)
                    batch[0].ticket._fail(e)
                else:
                    # a request-specific fault (e.g. one rider's unknown
                    # table name) must not fail its co-batched riders:
                    # retry each request as its own batch so only the
                    # offender errors
                    for req in batch:
                        self._serve_single(req)
                continue
            # the pool blocks on backend results + scatters back while this
            # thread loops on to stage/launch the next micro-batch
            try:
                self._pool.submit(self._finish_batch, batch_id, batch,
                                  spans, inflight, t_launch, tinfo)
            except RuntimeError:
                # pool already shut down (close() raced a long drain):
                # finish inline so no ticket is ever left hanging
                self._finish_batch(batch_id, batch, spans, inflight,
                                   t_launch, tinfo)

    def _serve_single(self, req: _Pending) -> None:
        """Rare fallback: serve one request as its own micro-batch, inline
        on the scheduler thread (used when a fused begin() failed, to
        isolate a request-specific fault to its origin)."""
        tinfo = None
        if self.tracer is not None and req.trace is not None:
            tinfo = {"formed": time.monotonic()}
        fused, spans = coalesce([req])
        if tinfo is not None:
            tinfo["coalesced"] = time.monotonic()
        t_launch = time.monotonic()
        try:
            inflight = self.backend.begin(fused, version=req.version,
                                          strict=req.strict)
            if tinfo is not None:
                tinfo["begun"] = time.monotonic()
                tinfo["finish_start"] = tinfo["begun"]
            result = self.backend.finish(inflight)
        except BaseException as e:  # noqa: BLE001
            self.stats.on_failure(1, req.qos)
            req.ticket._fail(e)
            return
        now = time.monotonic()
        if tinfo is not None:
            tinfo["launch"] = t_launch
            tinfo["finish_end"] = now
        self._batcher.observe_service_time(now - t_launch)
        self.stats.on_batch(1, inflight.keys_requested,
                            inflight.keys_deviceside, inflight.launches)
        self._deliver(req, result, spans[0], next(self._batch_ids), now,
                      tinfo)

    def _trace_spans(self, req: _Pending, tinfo: Optional[dict],
                     version: int, batch_id: int, t_scatter: float,
                     t_end: float) -> list:
        """Build this request's span timeline (obs/trace.py taxonomy:
        admission -> lane_wait -> coalesce -> version_pin -> begin ->
        device -> finish -> scatter under a ``serve`` root), record it in
        the tracer, and return the spans."""
        tracer = self.tracer
        ctx = req.trace
        tid = ctx["trace_id"]
        proc = tracer.proc
        root = Span(tid, "serve", req.t_submit, t_end,
                    parent_id=ctx.get("parent_id"), proc=proc,
                    tags={"qos": req.qos.name, "batch_id": batch_id,
                          "version": version, "n_keys": req.n_keys})
        pid = root.span_id
        # submit() stamps t_admit after admit() returns; a fast scheduler
        # can deliver before that lands — fall back to the submit stamp
        t_admit = ctx.get("t_admit", req.t_submit)
        out = [root, Span(tid, "admission", req.t_submit, t_admit,
                          parent_id=pid, proc=proc)]
        if tinfo is not None:
            chain = (("lane_wait", t_admit, tinfo["formed"]),
                     ("coalesce", tinfo["formed"], tinfo["coalesced"]),
                     ("version_pin", tinfo["coalesced"], tinfo["launch"]),
                     ("begin", tinfo["launch"], tinfo["begun"]),
                     ("device", tinfo["begun"], tinfo["finish_start"]),
                     ("finish", tinfo["finish_start"],
                      tinfo["finish_end"]))
            for name, t0, t1 in chain:
                tags = {"version": version} if name == "version_pin" \
                    else None
                out.append(Span(tid, name, t0, t1, parent_id=pid,
                                proc=proc, tags=tags))
        out.append(Span(tid, "scatter", t_scatter, t_end, parent_id=pid,
                        proc=proc))
        tracer.record(out)
        return out

    def _deliver(self, req: _Pending, result, span, batch_id: int,
                 now: float, tinfo: Optional[dict] = None) -> None:
        """Scatter one request's slice out of a finished batch, enforce its
        ``min_version`` requirement, record stats, wake the ticket."""
        latency = now - req.t_submit
        try:
            req.consistency.check(result.version)
        except ConsistencyError as e:
            self.stats.on_failure(1, req.qos)
            req.ticket._fail(e)
            return
        traced = self.tracer is not None and req.trace is not None
        t_scatter = time.monotonic() if traced else 0.0
        sliced = scatter(result, span)
        met = None if req.deadline is None else now <= req.deadline
        # stats BEFORE waking the ticket: a client observing its result
        # (e.g. warmup join followed by reset_stats) must never find its
        # own completion still unrecorded
        self.stats.on_complete(latency, met, req.qos)
        trace_wire = None
        if traced:
            spans = self._trace_spans(req, tinfo, result.version, batch_id,
                                      t_scatter, time.monotonic())
            trace_wire = [s.to_wire() for s in spans]
        req.ticket._complete(
            QueryResponse.from_result(sliced, qos=req.qos,
                                      latency_s=latency, batch_id=batch_id,
                                      trace=trace_wire),
            batch_id, latency)

    def _finish_batch(self, batch_id: int, batch: list, spans: list,
                      inflight, t_launch: float,
                      tinfo: Optional[dict] = None) -> None:
        try:
            try:
                if tinfo is not None:
                    tinfo["finish_start"] = time.monotonic()
                result = self.backend.finish(inflight)
            except BaseException as e:  # noqa: BLE001
                for req in batch:
                    self.stats.on_failure(1, req.qos)
                    req.ticket._fail(e)
                return
            finally:
                self._inflight.release()
            now = time.monotonic()
            if tinfo is not None:
                tinfo["launch"] = t_launch
                tinfo["finish_end"] = now
            self._batcher.observe_service_time(now - t_launch)
            self.stats.on_batch(len(batch), inflight.keys_requested,
                                inflight.keys_deviceside, inflight.launches,
                                service_s=now - t_launch)
            for req, span in zip(batch, spans):
                self._deliver(req, result, span, batch_id, now, tinfo)
        finally:
            # whatever path settled (or raised), this batch is no longer
            # in flight — close() must not wait on or re-fail it
            self._inflight_reqs.pop(batch_id, None)
