"""QueryServer — concurrent batch-query serving over a MultiTableEngine.

The paper's headline is answering batch queries "within milliseconds" under
heavy concurrent traffic; the engine (core/engine.py) supplies the fused,
deduplicated, version-pinned query, and this module supplies the serving
layer in front of it:

  - many concurrent clients ``submit`` small per-table key sets, each with
    an optional latency budget;
  - the scheduler (serve/scheduler.py) coalesces them into deadline-aware
    micro-batches — cross-REQUEST dedup rides the engine's existing
    per-batch dedup, since the fused request is just one big engine batch;
  - each micro-batch pins exactly one engine version for its whole lifetime
    (``engine.begin`` resolves the build once; the build object is
    immutable), so concurrent ``publish``/``publish_delta`` calls can never
    produce a mixed-version batch;
  - launch/finish are double-buffered: the single scheduler thread stages +
    launches batch i+1 while the worker pool blocks on batch i's device
    results and scatters rows back to each request's ticket.

Example::

    server = QueryServer(engine, BatchPolicy(max_batch_keys=4096))
    ticket = server.submit({"item_attr": ids}, budget_s=0.050)
    result = ticket.result()          # engine QueryResult, request-sliced
    print(server.stats_snapshot().summary())
    server.close()

Shedding surfaces as typed errors (``QueueFullError``, ``DeadlineError``)
from ``submit``/``Ticket.result`` — see serve/scheduler.py.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.core.engine import MultiTableEngine, QueryResult
from repro.serve.scheduler import (BatchPolicy, MicroBatcher, ServerStats,
                                   ServerClosedError, StatsSnapshot, Ticket,
                                   _Pending, coalesce, scatter)


class QueryServer:
    """Admission + micro-batching + double-buffered execution in front of a
    ``MultiTableEngine``.  Thread-safe: ``submit``/``query`` may be called
    from any number of client threads; ``publish``/``publish_delta`` on the
    engine may run concurrently from an updater thread."""

    def __init__(self, engine: MultiTableEngine,
                 policy: Optional[BatchPolicy] = None, *,
                 workers: int = 2, pipeline_depth: int = 2,
                 start: bool = True):
        if pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        self.engine = engine
        self.policy = policy or BatchPolicy()
        self.stats = ServerStats(self.policy)
        self._batcher = MicroBatcher(self.policy, self.stats)
        self._pool = ThreadPoolExecutor(max_workers=max(workers, 1),
                                        thread_name_prefix="qs-finish")
        # bounds batches between launch and finish: depth 2 is the classic
        # double buffer (one in flight on device, one being finished)
        self._inflight = threading.BoundedSemaphore(pipeline_depth)
        self._batch_ids = itertools.count()
        self._scheduler: Optional[threading.Thread] = None
        self._closed = False
        if start:
            self.start()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._scheduler is not None:
            return
        self._scheduler = threading.Thread(
            target=self._run, name="qs-scheduler", daemon=True)
        self._scheduler.start()

    def close(self, timeout: float = 10.0) -> None:
        """Stop admitting, drain queued batches, join the pipeline.  Any
        request no scheduler will ever serve (server never started, or the
        join timed out mid-drain) has its ticket failed with
        ``ServerClosedError`` rather than left hanging."""
        self._closed = True
        self._batcher.close()
        if self._scheduler is not None:
            self._scheduler.join(timeout)
            self._scheduler = None
        for req in self._batcher.drain():
            self.stats.on_failure(1)
            req.ticket._fail(ServerClosedError("server closed before the "
                                               "request was served"))
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "QueryServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # client faces
    # ------------------------------------------------------------------
    def submit(self, request: dict, *, budget_s: Optional[float] = None,
               version: Optional[int] = None,
               strict: bool = False) -> Ticket:
        """Enqueue one request (``{table: keys}``) and return its ticket.

        Raises ``QueueFullError`` / ``DeadlineError`` / ``ServerClosedError``
        at admission time when the request is shed by policy."""
        if self._closed:
            raise ServerClosedError("server is closed")
        if not request:
            raise ValueError("empty request: no tables")
        tables = {name: np.asarray(keys, dtype=np.uint64).ravel()
                  for name, keys in request.items()}
        now = time.monotonic()
        deadline = None if budget_s is None else now + budget_s
        ticket = Ticket(deadline)
        req = _Pending(tables=tables,
                       n_keys=sum(len(k) for k in tables.values()),
                       t_submit=now, deadline=deadline, version=version,
                       strict=strict, ticket=ticket)
        self.stats.on_submit()
        try:
            self._batcher.admit(req)    # raises the typed shed errors
        except ServerClosedError:
            # keep the snapshot reconcilable (submitted == completed +
            # failed + shed): a close() racing this submit is a failure,
            # not a silently vanished request
            self.stats.on_failure(1)
            raise
        return ticket

    def query(self, request: dict, *, budget_s: Optional[float] = None,
              version: Optional[int] = None, strict: bool = False,
              timeout: Optional[float] = None) -> QueryResult:
        """Synchronous convenience: submit + wait.  Exceptions that failed
        the micro-batch (e.g. ``VersionEvictedError`` under ``strict``) or
        shed the request re-raise here."""
        return self.submit(request, budget_s=budget_s, version=version,
                           strict=strict).result(timeout)

    def stats_snapshot(self) -> StatsSnapshot:
        return self.stats.snapshot()

    def reset_stats(self) -> None:
        """Fresh counters/latencies — start a measurement window after
        warmup (cold jit compiles otherwise dominate the percentiles)."""
        self.stats = ServerStats(self.policy)
        self._batcher.stats = self.stats

    @property
    def queue_depth(self) -> int:
        return self._batcher.depth()

    # ------------------------------------------------------------------
    # scheduler pipeline
    # ------------------------------------------------------------------
    def _run(self) -> None:
        while True:
            batch = self._batcher.next_batch()
            if batch is None:
                return
            self._inflight.acquire()
            batch_id = next(self._batch_ids)
            fused, spans = coalesce(batch)
            t_launch = time.monotonic()
            try:
                # stage pins ONE version for the whole micro-batch; the
                # build reference keeps that version's tables alive even if
                # a concurrent publish evicts it from the window mid-flight
                inflight = self.engine.begin(
                    fused, version=batch[0].version, strict=batch[0].strict)
            except BaseException as e:  # noqa: BLE001
                self._inflight.release()
                if len(batch) == 1:
                    self.stats.on_failure(1)
                    batch[0].ticket._fail(e)
                else:
                    # a request-specific fault (e.g. one rider's unknown
                    # table name) must not fail its co-batched riders:
                    # retry each request as its own batch so only the
                    # offender errors
                    for req in batch:
                        self._serve_single(req)
                continue
            # the pool blocks on device results + scatters back while this
            # thread loops on to stage/launch the next micro-batch
            try:
                self._pool.submit(self._finish_batch, batch_id, batch,
                                  spans, inflight, t_launch)
            except RuntimeError:
                # pool already shut down (close() raced a long drain):
                # finish inline so no ticket is ever left hanging
                self._finish_batch(batch_id, batch, spans, inflight,
                                   t_launch)

    def _serve_single(self, req) -> None:
        """Rare fallback: serve one request as its own micro-batch, inline
        on the scheduler thread (used when a fused begin() failed, to
        isolate a request-specific fault to its origin)."""
        fused, spans = coalesce([req])
        t_launch = time.monotonic()
        try:
            inflight = self.engine.begin(fused, version=req.version,
                                         strict=req.strict)
            result = self.engine.finish(inflight)
        except BaseException as e:  # noqa: BLE001
            self.stats.on_failure(1)
            req.ticket._fail(e)
            return
        now = time.monotonic()
        self._batcher.observe_service_time(now - t_launch)
        latency = now - req.t_submit
        met = None if req.deadline is None else now <= req.deadline
        staged = inflight.staged
        self.stats.on_batch(1, staged.keys_requested,
                            staged.keys_deviceside, inflight.launches)
        self.stats.on_complete(latency, met)
        req.ticket._complete(scatter(result, spans[0]),
                             next(self._batch_ids), latency)

    def _finish_batch(self, batch_id: int, batch: list, spans: list,
                      inflight, t_launch: float) -> None:
        try:
            result = self.engine.finish(inflight)
        except BaseException as e:  # noqa: BLE001
            self.stats.on_failure(len(batch))
            for req in batch:
                req.ticket._fail(e)
            return
        finally:
            self._inflight.release()
        now = time.monotonic()
        self._batcher.observe_service_time(now - t_launch)
        staged = inflight.staged
        self.stats.on_batch(len(batch), staged.keys_requested,
                            staged.keys_deviceside, inflight.launches)
        for req, span in zip(batch, spans):
            latency = now - req.t_submit
            met = None if req.deadline is None else now <= req.deadline
            # stats BEFORE waking the ticket: a client observing its result
            # (e.g. warmup join followed by reset_stats) must never find
            # its own completion still unrecorded
            self.stats.on_complete(latency, met)
            req.ticket._complete(scatter(result, span), batch_id, latency)
