"""Serving entry points per family — what `decode_*` / `serve_*` /
`retrieval_*` shape cells lower."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import lm as lm_mod
from repro.models import recsys as rec_mod


def lm_decode_fn(cfg, mesh, mi):
    def step(params, token, pos, caches):
        return lm_mod.lm_decode_step(params, cfg, token, pos, caches, mesh,
                                     mi)
    return step


def lm_prefill_fn(cfg, mesh, mi):
    def step(params, tokens):
        h, _ = lm_mod.lm_backbone(params, cfg, tokens, mesh, mi)
        logits_last = lm_mod.lm_logits(params, cfg, h[:, -1:])[:, 0]
        return logits_last
    return step


def recsys_score_fn(cfg, mesh, mi, lookup_impl: str = "xla"):
    def step(params, batch):
        return rec_mod.recsys_score(params, cfg, batch, mi, mesh,
                                    lookup_impl)
    return step


def retrieval_fn(cfg, mesh, mi, top_k: int = 100):
    def step(params, batch, cand_ids, cand_cats):
        return rec_mod.retrieval_scores(params, cfg, batch, cand_ids,
                                        cand_cats, mi, top_k)
    return step


def bulk_rank_fn(cfg, mesh, mi, top_k: int = 100):
    """retrieval_cand for pointwise archs: score 1M candidate items for one
    user by broadcasting the user context over the candidate batch."""
    fwd = rec_mod.FORWARD[cfg.arch]

    def step(params, batch):
        logits = fwd(params, cfg, batch, mi)
        return jax.lax.top_k(logits, top_k)
    return step
