"""Serving entry points per family — what `decode_*` / `serve_*` /
`retrieval_*` shape cells lower.

The recsys path can serve its feature columns out of a
``core.engine.MultiTableEngine``: one fused, deduplicated batch query
resolves every attribute/embedding table the request touches before the
jitted scoring step runs (paper Fig 2's query side in front of the model).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as cm
from repro.models import lm as lm_mod
from repro.models import recsys as rec_mod


def lm_decode_fn(cfg, mesh, mi):
    def step(params, token, pos, caches):
        return lm_mod.lm_decode_step(params, cfg, token, pos, caches, mesh,
                                     mi)
    return step


def lm_prefill_fn(cfg, mesh, mi):
    def step(params, tokens):
        h, _ = lm_mod.lm_backbone(params, cfg, tokens, mesh, mi)
        logits_last = lm_mod.lm_logits(params, cfg, h[:, -1:])[:, 0]
        return logits_last
    return step


def recsys_score_fn(cfg, mesh, mi, lookup_impl: str = "xla",
                    feature_engine=None,
                    feature_fields: Optional[Sequence[tuple]] = None,
                    feature_server=None,
                    feature_budget_s: Optional[float] = None,
                    feature_client=None,
                    feature_qos="RANKING"):
    """Scoring step; with a feature source the step first resolves
    ``feature_fields`` — ``(table_name, batch_field)`` pairs — in ONE fused
    batch query and splices the returned float32 rows into the batch's
    dense columns before the model runs.

    The feature source is a ``feature_client`` (``api.FeatureClient``, the
    API-v2 session — over a QueryServer its lookups coalesce with other
    in-flight scoring requests into QoS-laned micro-batches).  The PR-3
    shims remain for one release: ``feature_engine`` (a MultiTableEngine)
    and ``feature_server`` (a QueryServer) each wrap themselves in a
    client.  Exactly one of the three may be given.  Scoring lookups ride
    the ``feature_qos`` lane (default RANKING — this is the user-facing
    scoring path) with ``feature_budget_s`` as their latency budget."""
    def step(params, batch):
        return rec_mod.recsys_score(params, cfg, batch, mi, mesh,
                                    lookup_impl)

    sources = [s for s in (feature_engine, feature_server, feature_client)
               if s is not None]
    if len(sources) > 1:
        raise ValueError("pass exactly one of feature_client / "
                         "feature_engine / feature_server")
    if not sources:
        return step

    from repro.api.client import FeatureClient
    from repro.api.types import QoSClass
    client = (feature_client if feature_client is not None
              else FeatureClient(sources[0]))
    qos = QoSClass.parse(feature_qos)

    def resolve(request):
        return client.query(request, qos=qos, budget_s=feature_budget_s)

    fields = list(feature_fields or ())
    if not fields:
        raise ValueError("feature engine/server given but no feature_fields")
    names = [t for t, _ in fields]
    if len(set(names)) != len(names):
        raise ValueError("duplicate table names in feature_fields: one "
                         "fused request carries one key set per table")

    def step_with_store(params, batch):
        n_rows = len(np.asarray(batch["dense"]))
        request = {}
        for table, field in fields:
            ids = np.asarray(batch[field])
            if ids.ndim != 1 or len(ids) != n_rows:
                raise ValueError(
                    f"feature field {field!r} must be 1-D of length "
                    f"{n_rows} (one key per example), got {ids.shape}")
            request[table] = ids.astype(np.uint64)
        res = resolve(request)                   # one fused query, pinned
        cols = []
        for table, _field in fields:
            tr = res[table]
            if tr.values is not None:            # embedding: float32 rows
                rows = np.ascontiguousarray(tr.values).view(np.float32)
                rows = rows.reshape(len(tr.found), -1)
            else:                                # scalar: payload column
                rows = tr.payloads.astype(np.float32)[:, None]
            rows = rows * tr.found[:, None]      # misses contribute zeros
            cols.append(rows)
        feats = np.concatenate(cols, axis=-1)
        dense = np.array(batch["dense"])
        d = min(feats.shape[1], dense.shape[1])
        dense[:, :d] = feats[:, :d]
        batch = dict(batch)
        batch["dense"] = jnp.asarray(dense)
        return step(params, batch)

    return step_with_store


def retrieval_fn(cfg, mesh, mi, top_k: int = 100):
    def step(params, batch, cand_ids, cand_cats):
        return rec_mod.retrieval_scores(params, cfg, batch, cand_ids,
                                        cand_cats, mi, top_k)
    return step


def bulk_rank_fn(cfg, mesh, mi, top_k: int = 100):
    """retrieval_cand for pointwise archs: score 1M candidate items for one
    user by broadcasting the user context over the candidate batch."""
    fwd = rec_mod.FORWARD[cfg.arch]

    def step(params, batch):
        logits = fwd(params, cfg, batch, mi)
        return jax.lax.top_k(logits, top_k)
    return step
