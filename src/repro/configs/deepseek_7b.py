"""deepseek-7b — dense llama-arch decoder [arXiv:2401.02954; hf].
30L d_model=4096 32H (GQA kv=32 => MHA) d_ff=11008 vocab=102400."""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="deepseek-7b", n_layers=30, d_model=4096, n_heads=32,
    n_kv_heads=32, head_dim=128, d_ff=11008, vocab=102400,
    attn_type="gqa", ffn_type="swiglu", rope_base=10000.0, q_chunk=512,
)

SMOKE = LMConfig(
    name="deepseek-7b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=160, vocab=512,
    attn_type="gqa", ffn_type="swiglu", q_chunk=16, remat=False,
)
