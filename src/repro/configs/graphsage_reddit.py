"""graphsage-reddit [arXiv:1706.02216]. 2 layers, d_hidden=128, mean
aggregator, sample sizes 25-10.  d_feat/n_classes come from each cell
(cora / reddit / ogbn-products / molecule)."""
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="graphsage-reddit", n_layers=2, d_hidden=128, d_feat=602,
    n_classes=41, aggregator="mean", fanouts=(25, 10),
)

SMOKE = GNNConfig(
    name="graphsage-smoke", n_layers=2, d_hidden=16, d_feat=24,
    n_classes=5, aggregator="mean", fanouts=(4, 3),
)
