"""din — Deep Interest Network [arXiv:1706.06978].
embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80 target-attention."""
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="din", arch="din", embed_dim=18, seq_len=100,
    item_vocab=100_000_000, cat_vocab=100_000, n_dense=8,
    attn_mlp=(80, 40), mlp=(200, 80),
)

SMOKE = RecsysConfig(
    name="din-smoke", arch="din", embed_dim=18, seq_len=10,
    item_vocab=1000, cat_vocab=50, n_dense=8,
    attn_mlp=(16, 8), mlp=(32, 16),
)
