"""two-tower-retrieval — sampled-softmax retrieval [Yi et al., RecSys'19].
embed_dim=256 tower_mlp=1024-512-256 dot interaction."""
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="two-tower-retrieval", arch="two_tower", embed_dim=256,
    seq_len=50, item_vocab=10_000_000, cat_vocab=100_000,
    user_vocab=20_000_000, n_dense=8, tower_mlp=(1024, 512, 256),
)

SMOKE = RecsysConfig(
    name="two-tower-smoke", arch="two_tower", embed_dim=32,
    seq_len=8, item_vocab=1000, cat_vocab=50, user_vocab=2000,
    n_dense=8, tower_mlp=(64, 32),
)
