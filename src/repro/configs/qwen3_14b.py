"""qwen3-14b — dense GQA with qk-norm [hf:Qwen/Qwen3-14B].
40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936."""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40,
    n_kv_heads=8, head_dim=128, d_ff=17408, vocab=151936,
    attn_type="gqa", ffn_type="swiglu", qk_norm=True,
    rope_base=1000000.0, q_chunk=512,
)

SMOKE = LMConfig(
    name="qwen3-14b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=160, vocab=512,
    attn_type="gqa", ffn_type="swiglu", qk_norm=True, q_chunk=16,
    remat=False,
)
