"""deepfm [arXiv:1703.04247]. 39 sparse fields, embed_dim=10,
mlp=400-400-400, FM interaction (fused Pallas kernel on TPU)."""
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="deepfm", arch="deepfm", embed_dim=10, n_sparse_fields=39,
    field_vocab=1_000_000, n_dense=13, mlp=(400, 400, 400),
)

SMOKE = RecsysConfig(
    name="deepfm-smoke", arch="deepfm", embed_dim=10, n_sparse_fields=7,
    field_vocab=100, n_dense=13, mlp=(32, 32),
)
