"""Architecture registry: 10 assigned archs (+ the paper's own feature-store
config).  Each arch module defines CONFIG (exact public config), SMOKE
(reduced same-family config for CPU tests) and the registry maps its four
assigned shape cells.

``--arch <id>`` everywhere resolves through ``get(arch_id)``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional


@dataclasses.dataclass(frozen=True)
class Cell:
    name: str
    kind: str          # train | prefill | decode | rec_train | rec_serve |
    #                    rec_retrieval | gnn_full | gnn_minibatch | gnn_molecule
    dims: dict


LM_CELLS = (
    Cell("train_4k", "train", {"seq": 4096, "batch": 256}),
    Cell("prefill_32k", "prefill", {"seq": 32768, "batch": 32}),
    Cell("decode_32k", "decode", {"seq": 32768, "batch": 128}),
    Cell("long_500k", "decode", {"seq": 524288, "batch": 1}),
)

GNN_CELLS = (
    Cell("full_graph_sm", "gnn_full",
         {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7}),
    Cell("minibatch_lg", "gnn_minibatch",
         {"batch_nodes": 1024, "fanouts": (15, 10), "d_feat": 602,
          "n_classes": 41, "n_nodes": 232_965, "n_edges": 114_615_892}),
    Cell("ogb_products", "gnn_full",
         {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
          "n_classes": 47}),
    Cell("molecule", "gnn_molecule",
         {"n_graphs": 128, "n_nodes": 30, "n_edges": 64, "d_feat": 32,
          "n_classes": 10}),
)

REC_CELLS = (
    Cell("train_batch", "rec_train", {"batch": 65536}),
    Cell("serve_p99", "rec_serve", {"batch": 512}),
    Cell("serve_bulk", "rec_serve", {"batch": 262144}),
    Cell("retrieval_cand", "rec_retrieval",
         {"batch": 1, "n_candidates": 1_000_000}),
)

ARCHS = {
    "deepseek-7b": ("repro.configs.deepseek_7b", "lm", LM_CELLS),
    "qwen3-14b": ("repro.configs.qwen3_14b", "lm", LM_CELLS),
    "nemotron-4-340b": ("repro.configs.nemotron_4_340b", "lm", LM_CELLS),
    "deepseek-v3-671b": ("repro.configs.deepseek_v3_671b", "lm", LM_CELLS),
    "qwen3-moe-235b-a22b": ("repro.configs.qwen3_moe_235b", "lm", LM_CELLS),
    "graphsage-reddit": ("repro.configs.graphsage_reddit", "gnn", GNN_CELLS),
    "din": ("repro.configs.din", "recsys", REC_CELLS),
    "bst": ("repro.configs.bst", "recsys", REC_CELLS),
    "two-tower-retrieval": ("repro.configs.two_tower_retrieval", "recsys",
                            REC_CELLS),
    "deepfm": ("repro.configs.deepfm", "recsys", REC_CELLS),
    # the paper's own workload (feature-store serving; benchmarks/T4)
    "bili-feature-store": ("repro.configs.bili_feature_store", "kv", ()),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str
    config: Any
    smoke: Any
    cells: tuple


def get(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    module, family, cells = ARCHS[arch_id]
    mod = importlib.import_module(module)
    return ArchSpec(arch_id=arch_id, family=family, config=mod.CONFIG,
                    smoke=mod.SMOKE, cells=cells)


def all_arch_ids(include_kv: bool = False) -> list[str]:
    return [a for a, (_, fam, _) in ARCHS.items()
            if include_kv or fam != "kv"]


def cell_by_name(spec: ArchSpec, name: str) -> Cell:
    for c in spec.cells:
        if c.name == name:
            return c
    raise KeyError(f"{spec.arch_id} has no cell {name!r}")
