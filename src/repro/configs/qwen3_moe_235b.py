"""qwen3-moe-235b-a22b — GQA(kv=4) + 128-expert top-8 MoE
[hf:Qwen/Qwen3-235B-A22B]. 94L d_model=4096 64H d_ff(expert)=1536
vocab=151936."""
from repro.models.lm import LMConfig
from repro.models.moe import MoEConfig

CONFIG = LMConfig(
    name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
    n_kv_heads=4, head_dim=128, d_ff=1536, vocab=151936,
    attn_type="gqa", ffn_type="swiglu", qk_norm=True,
    rope_base=1000000.0, q_chunk=512, n_dense_layers=0,
    moe=MoEConfig(d_model=4096, d_ff=1536, n_experts=128, top_k=8,
                  n_shared=0, capacity_factor=1.25, aux_weight=0.001),
)

SMOKE = LMConfig(
    name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=96, vocab=512,
    attn_type="gqa", ffn_type="swiglu", qk_norm=True, q_chunk=16,
    remat=False, n_dense_layers=0,
    moe=MoEConfig(d_model=64, d_ff=32, n_experts=8, top_k=2, n_shared=0),
)
