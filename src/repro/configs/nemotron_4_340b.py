"""nemotron-4-340b — dense GQA with squared-ReLU FFN [arXiv:2402.16819].
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000."""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="nemotron-4-340b", n_layers=96, d_model=18432, n_heads=96,
    n_kv_heads=8, head_dim=192, d_ff=73728, vocab=256000,
    attn_type="gqa", ffn_type="squared_relu", rope_base=10000.0,
    q_chunk=512,
)

SMOKE = LMConfig(
    name="nemotron-4-340b-smoke", n_layers=2, d_model=96, n_heads=4,
    n_kv_heads=2, head_dim=24, d_ff=384, vocab=512,
    attn_type="gqa", ffn_type="squared_relu", q_chunk=16, remat=False,
)
