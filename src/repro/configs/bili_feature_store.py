"""The paper's own workload: a high-traffic item-feature table
(§3.2 Latency: 40M items, 1KB per item, ~700k key-seeks/s peak) served by
the NeighborKV batch-query architecture."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class FeatureStoreConfig:
    name: str = "bili-feature-store"
    n_items: int = 40_000_000
    value_bytes: int = 1024
    hot_fraction: float = 0.1
    max_shard_bytes: int = 1 << 32          # 4 GB shards
    load_factor: float = 0.8
    peak_kps: int = 700_000


CONFIG = FeatureStoreConfig()
SMOKE = FeatureStoreConfig(name="bili-feature-store-smoke", n_items=20_000,
                           value_bytes=64, max_shard_bytes=1 << 18)
