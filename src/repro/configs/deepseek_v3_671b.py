"""deepseek-v3-671b — MLA + shared/routed MoE + MTP [arXiv:2412.19437].
61L d_model=7168 128H d_ff(expert)=2048 vocab=129280, 1 shared + 256 routed
top-8, first 3 layers dense (d_ff=18432), MTP depth 1."""
from repro.models.lm import LMConfig
from repro.models.moe import MoEConfig

CONFIG = LMConfig(
    name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
    n_kv_heads=128, head_dim=128, d_ff=18432, vocab=129280,
    attn_type="mla", ffn_type="swiglu", rope_base=10000.0, q_chunk=512,
    n_dense_layers=3, mtp_depth=1,
    moe=MoEConfig(d_model=7168, d_ff=2048, n_experts=256, top_k=8,
                  n_shared=1, shared_d_ff=2048, capacity_factor=1.25,
                  aux_weight=0.0001),
)

SMOKE = LMConfig(
    name="deepseek-v3-671b-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=160, vocab=512,
    attn_type="mla", ffn_type="swiglu", q_chunk=16, remat=False,
    n_dense_layers=1, mtp_depth=1,
    moe=MoEConfig(d_model=64, d_ff=32, n_experts=8, top_k=2, n_shared=1,
                  shared_d_ff=32),
)
