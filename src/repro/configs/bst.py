"""bst — Behavior Sequence Transformer (Alibaba) [arXiv:1905.06874].
embed_dim=32 seq_len=20 1 block 8 heads mlp=1024-512-256."""
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="bst", arch="bst", embed_dim=32, seq_len=20,
    item_vocab=100_000_000, cat_vocab=100_000, n_dense=8,
    n_blocks=1, n_heads=8, mlp=(1024, 512, 256),
)

SMOKE = RecsysConfig(
    name="bst-smoke", arch="bst", embed_dim=16, seq_len=6,
    item_vocab=1000, cat_vocab=50, n_dense=8,
    n_blocks=1, n_heads=4, mlp=(32, 16),
)
