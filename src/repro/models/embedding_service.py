"""Model-parallel embedding layer backed by the batch-query architecture.

Tables are row-sharded over the mesh 'model' axis — the on-chip image of the
paper's automatic table sharding (DESIGN.md §4).  Two lookup paths:

  * 'xla'  (default): jnp.take / EmbeddingBag against the sharded table;
    the SPMD partitioner inserts the gather collectives.  Differentiable,
    used by training.
  * 'a2a': the explicit batch-query protocol (core/distributed.py) — the
    beyond-paper serving path benchmarked in §Perf.

IDs may be raw 64-bit entity ids; ``hash_ids`` folds them into the table's
row space with the same 32-bit mix the NeighborHash index uses (the
frequency-hashing trick of [39] in the paper's related work).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import hashcore as hc
from repro.kernels import ref as kref
from repro.models import common as cm
from repro.models.common import Boxed, MeshInfo


@dataclasses.dataclass(frozen=True)
class TableCfg:
    name: str
    vocab: int
    dim: int


def table_init(key, t: TableCfg, dtype=jnp.float32) -> Boxed:
    return Boxed(cm.normal_init(key, (t.vocab, t.dim), 0.05, dtype),
                 P("model", None))


def hash_ids(ids: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """64-bit-safe fold of raw ids into [0, vocab) (negative ids = padding,
    preserved)."""
    lo = ids.astype(jnp.uint32)
    hi = (ids >> 31).astype(jnp.uint32)      # int32-safe 'high' part
    h = hc.hash64_jnp(hi, lo) % jnp.uint32(vocab)
    return jnp.where(ids < 0, -1, h.astype(jnp.int32))


def embed_lookup(table: jnp.ndarray, ids: jnp.ndarray,
                 mi: MeshInfo) -> jnp.ndarray:
    """Single-id lookup: ids [...] -> [..., D]."""
    safe = jnp.maximum(ids, 0)
    out = jnp.take(table, safe, axis=0)
    out = jnp.where((ids >= 0)[..., None], out, 0)
    return mi.shard(out, mi.dp)


def embed_bag(table: jnp.ndarray, ids: jnp.ndarray,
              weights: Optional[jnp.ndarray], mode: str,
              mi: MeshInfo) -> jnp.ndarray:
    """Multi-hot bag lookup: ids [B, L] (-1 pad) -> [B, D]."""
    out = kref.embedding_bag(table, ids, weights, mode)
    return mi.shard(out, mi.dp, None)


# ---------------------------------------------------------------------------
# the paper's batch-query protocol as the serving lookup path (§Perf C1):
# ids sharded over the data axes, table row-blocks over 'model'; each device
# buckets its ids by owning shard, all_to_all's the ids (4 B each), answers
# with a LOCAL gather, and all_to_all's the rows back — instead of letting
# the partitioner all-gather table blocks.  Serving-only (no grad).
# ---------------------------------------------------------------------------
def embed_bag_psum(table: jnp.ndarray, ids: jnp.ndarray, mode: str, mesh,
                   mi: MeshInfo, comm_dtype=jnp.bfloat16) -> jnp.ndarray:
    """Bag lookup via shard-local partial reduce + low-precision psum
    (§Perf C2): each table shard sums the rows IT owns for every bag, then
    one psum of [B, D] in ``comm_dtype`` combines — collective bytes are
    B·D·sizeof(comm_dtype), independent of bag length, and halved vs the
    partitioner's fp32 all-reduce.  Serving path (no grad)."""
    from repro.core.compat import shard_map
    n_shards = mi.sizes.get("model", 1)
    v, d = table.shape
    if n_shards <= 1 or v % n_shards or mesh is None:
        return embed_bag(table, ids, None, mode, mi)
    rows_per_shard = v // n_shards
    dp = mi.dp
    bspec = dp if (dp and ids.shape[0] % max(mi.axis_size(dp), 1) == 0) \
        else None

    def body(tbl, ids_loc):
        i = jax.lax.axis_index("model")
        local = ids_loc - i * rows_per_shard
        mine = (ids_loc >= 0) & (local >= 0) & (local < rows_per_shard)
        rows = jnp.take(tbl, jnp.clip(local, 0, rows_per_shard - 1), axis=0)
        rows = rows * mine[..., None].astype(rows.dtype)
        part = rows.sum(axis=1).astype(comm_dtype)          # [B_loc, D]
        out = jax.lax.psum(part, "model").astype(table.dtype)
        if mode == "mean":
            cnt = jax.lax.psum(
                mine.sum(axis=1).astype(jnp.float32), "model")
            out = out / jnp.maximum(cnt, 1.0)[:, None]
        return out

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("model", None), P(bspec)),
                   out_specs=P(bspec), check_vma=False)
    return fn(table, ids)


def embed_lookup_a2a(table: jnp.ndarray, ids: jnp.ndarray, mesh,
                     mi: MeshInfo, capacity_factor: float = 1.5
                     ) -> jnp.ndarray:
    from repro.core.compat import shard_map
    from repro.core.distributed import (route_by_owner, scatter_to_buffers,
                                        gather_from_buffers)
    n_shards = mi.sizes.get("model", 1)
    v, d = table.shape
    if n_shards <= 1 or v % n_shards or mesh is None:
        return embed_lookup(table, ids, mi)
    rows_per_shard = v // n_shards
    lead_shape = ids.shape
    dp = mi.dp
    n_lead = lead_shape[0]
    bspec = dp if (dp and n_lead % max(mi.axis_size(dp), 1) == 0) else None
    n_loc_ids = (np.prod(lead_shape) //
                 max(mi.axis_size(bspec) if bspec else 1, 1))
    cap = max(int(np.ceil(n_loc_ids / n_shards * capacity_factor)), 1)

    def body(tbl, ids_loc):
        flat = ids_loc.reshape(-1)
        safe = jnp.maximum(flat, 0)
        owner = (safe // rows_per_shard).astype(jnp.int32)
        r = route_by_owner(owner, n_shards, cap)
        local_row = safe % rows_per_shard
        (send_ids,) = scatter_to_buffers(r, [local_row], n_shards, cap)
        recv_ids = jax.lax.all_to_all(send_ids, "model", 0, 0, tiled=True)
        rows = jnp.take(tbl, recv_ids.reshape(-1), axis=0)
        rows = rows.reshape(n_shards, cap, d)
        back = jax.lax.all_to_all(rows, "model", 0, 0, tiled=True)
        (out,) = gather_from_buffers(r, [back])
        valid = (flat >= 0) & r.kept
        out = jnp.where(valid[:, None], out, 0)
        return out.reshape(ids_loc.shape + (d,))

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("model", None), P(bspec)),
                   out_specs=P(bspec), check_vma=False)
    return fn(table, ids)
