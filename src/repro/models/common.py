"""Shared model substrate: boxed params (value + PartitionSpec), norms,
activations, rotary embeddings, sharding-constraint helpers.

Convention (DESIGN.md §6): mesh axes are ("pod", "data", "model") multi-pod or
("data", "model") single-pod.  Logical roles:

    DP   = ("pod", "data")  — batch dims
    TP   = "model"          — heads / ffn-hidden / vocab / experts / table rows
    SP   = "model"          — sequence dim of activations between blocks

Models are pure functions over nested-dict param trees.  Parameters are built
as `Boxed(value, spec)`; `unbox` splits into (params, specs) so the dry-run
can `jax.eval_shape` the init and build NamedShardings without allocating.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

DP = ("pod", "data")     # data-parallel mesh axes (pod may be absent)
TP = "model"


@dataclasses.dataclass
class Boxed:
    value: Any
    spec: P


jax.tree_util.register_pytree_node(
    Boxed,
    lambda b: ((b.value,), b.spec),
    lambda spec, ch: Boxed(ch[0], spec),
)


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def stack_specs(tree, prefix=(None,)):
    """After vmap-stacking layer params, prepend axes to every Boxed spec."""
    return jax.tree.map(
        lambda b: Boxed(b.value, P(*prefix, *b.spec)), tree,
        is_leaf=is_boxed)


def unbox(tree):
    params = jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)
    specs = jax.tree.map(lambda b: b.spec, tree, is_leaf=is_boxed)
    return params, specs


def dp_spec(mesh_axes) -> tuple:
    """The data-parallel axis group present in this mesh."""
    return tuple(a for a in DP if a in mesh_axes)


def adapt_spec(spec: P, mesh_axes) -> P:
    """Drop mesh axes not present (e.g. 'pod' on the single-pod mesh)."""
    out = []
    for part in spec:
        if part is None:
            out.append(None)
        elif isinstance(part, (tuple, list)):
            kept = tuple(a for a in part if a in mesh_axes)
            out.append(kept if kept else None)
        else:
            out.append(part if part in mesh_axes else None)
    return P(*out)


def cs(x, *spec_parts):
    """with_sharding_constraint against the ambient mesh (no-op outside jit
    or when the mesh has a single device)."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec_parts))
    except Exception:
        return x


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    """Trace-time mesh metadata so models can apply sharding constraints
    opportunistically (skip axes that don't divide a dim — e.g. 40 query
    heads on a 16-way 'model' axis stay replicated)."""
    axes: tuple
    sizes: dict

    @classmethod
    def from_mesh(cls, mesh) -> "MeshInfo":
        return cls(axes=tuple(mesh.axis_names),
                   sizes={a: int(s) for a, s in
                          zip(mesh.axis_names, mesh.devices.shape)})

    @classmethod
    def single(cls) -> "MeshInfo":
        return cls(axes=("data", "model"), sizes={"data": 1, "model": 1})

    @property
    def dp(self) -> tuple:
        return tuple(a for a in DP if a in self.axes)

    def axis_size(self, part) -> int:
        if part is None:
            return 1
        if isinstance(part, (tuple, list)):
            n = 1
            for a in part:
                n *= self.sizes.get(a, 1)
            return n
        return self.sizes.get(part, 1)

    def spec(self, *parts) -> P:
        """Adapt a spec to this mesh (drop absent axes)."""
        return adapt_spec(P(*parts), self.axes)

    def shard(self, x, *parts):
        """Constraint with divisibility checks; indivisible dims replicate."""
        parts = list(self.spec(*parts))
        while len(parts) < x.ndim:
            parts.append(None)
        fixed = []
        for dim, part in zip(x.shape, parts):
            n = self.axis_size(part)
            fixed.append(part if (n > 1 and dim % n == 0) or n == 1 else None)
        if all(p is None for p in fixed):
            return x
        return cs(x, *fixed)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def normal_init(key, shape, scale: float, dtype=jnp.float32):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


def dense_param(key, in_dim: int, out_dim: int, spec: P,
                dtype=jnp.float32) -> Boxed:
    scale = 1.0 / np.sqrt(in_dim)
    return Boxed(normal_init(key, (in_dim, out_dim), scale, dtype), spec)


def embed_param(key, vocab: int, dim: int, spec: P,
                dtype=jnp.float32) -> Boxed:
    return Boxed(normal_init(key, (vocab, dim), 0.02, dtype), spec)


def scale_param(dim: int, spec: P = P(None), dtype=jnp.float32) -> Boxed:
    return Boxed(jnp.ones((dim,), dtype), spec)


def keygen(key):
    """Infinite key splitter."""
    while True:
        key, sub = jax.random.split(key)
        yield sub


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------
def rms_norm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
            ).astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
            ).astype(dt)


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "squared_relu": squared_relu,
    "sigmoid": jax.nn.sigmoid,
    "prelu_like": jax.nn.leaky_relu,
    "dice_like": jax.nn.sigmoid,    # DIN's Dice ≈ data-adaptive sigmoid gate
}


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope_angles(positions: jnp.ndarray, dim: int, base: float = 10000.0):
    """positions [*, S] int -> (cos, sin) [*, S, dim/2] fp32."""
    inv = 1.0 / (base ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: [..., S, n_head, dim]; cos/sin broadcastable [..., S, 1, dim/2]."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Numerically-stable CE; logits [*, V] fp32-accumulated, labels [*].

    The gold logit is extracted with a fused one-hot reduce, NOT
    take_along_axis: gathering along a vocab-sharded axis would force the
    partitioner to all-gather the logits (13+ GB/device at 4k×100k); the
    one-hot compare+select+reduce stays shard-local and fuses."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    v = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(labels.dtype, logits.shape, logits.ndim
                                    - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0),
                   axis=-1)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def bce_with_logits(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree)
    return int(sum(np.prod(l.shape) for l in leaves))
