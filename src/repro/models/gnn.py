"""GraphSAGE (mean aggregator) in three execution regimes:

  * full-graph: message passing over a global edge list via
    ``jax.ops.segment_sum`` (JAX has no CSR SpMM — the scatter/segment path
    IS the system, per the assignment notes);
  * sampled minibatch: dense fanout trees (seed, [B,f1], [B,f1,f2]) produced
    by data/graph_sampler.py — fixed shapes, TPU-friendly;
  * batched small graphs (molecule): per-graph scatter-add with a graph dim.

Node features for sampled training are fetched through the batch-query layer
(one consistent table version per minibatch — DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common as cm
from repro.models.common import Boxed, MeshInfo

FSDP = ("pod", "data")


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    d_feat: int = 602
    n_classes: int = 41
    aggregator: str = "mean"
    fanouts: tuple = (25, 10)
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def sage_init(key, cfg: GNNConfig) -> dict:
    ks = cm.keygen(key)
    dt = cfg.jdtype
    dims = [cfg.d_feat] + [cfg.d_hidden] * cfg.n_layers
    layers = []
    for din, dout in zip(dims[:-1], dims[1:]):
        layers.append({
            "w_self": cm.dense_param(next(ks), din, dout, P(None, "model"),
                                     dt),
            "w_neigh": cm.dense_param(next(ks), din, dout, P(None, "model"),
                                      dt),
            "b": Boxed(jnp.zeros((dout,), dt), P(None)),
        })
    return {
        "layers": layers,
        "cls": cm.dense_param(next(ks), cfg.d_hidden, cfg.n_classes,
                              P(None, None), dt),
    }


def _combine(layer, h_self, h_neigh, last: bool):
    out = h_self @ layer["w_self"] + h_neigh @ layer["w_neigh"] + layer["b"]
    if not last:
        out = jax.nn.relu(out)
        # L2-normalize as in the paper (GraphSAGE §3.1)
        out = out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True),
                                1e-6)
    return out


# ---------------------------------------------------------------------------
# full-graph
# ---------------------------------------------------------------------------
def sage_full_graph(params: dict, cfg: GNNConfig, feats, edges,
                    mi: MeshInfo):
    """feats [N, F]; edges [2, E] (src -> dst).  Returns logits [N, C]."""
    src, dst = edges[0], edges[1]
    n = feats.shape[0]
    deg = jax.ops.segment_sum(jnp.ones_like(dst, dtype=feats.dtype), dst,
                              num_segments=n)
    deg = jnp.maximum(deg, 1.0)[:, None]
    h = feats
    for li, layer in enumerate(params["layers"]):
        msgs = jnp.take(h, src, axis=0)            # gather along edges
        msgs = mi.shard(msgs, mi.dp)
        agg = jax.ops.segment_sum(msgs, dst, num_segments=n) / deg
        h = _combine(layer, h, agg, last=False)
        h = mi.shard(h, mi.dp)
    return h @ params["cls"]


# ---------------------------------------------------------------------------
# sampled minibatch (dense fanout tree)
# ---------------------------------------------------------------------------
def sage_minibatch(params: dict, cfg: GNNConfig, block: dict, mi: MeshInfo):
    """block: seed_feats [B, F]; h1_feats [B, f1, F]; h2_feats [B, f1, f2, F];
    h1_mask [B, f1]; h2_mask [B, f1, f2].  2-layer SAGE. Returns [B, C]."""
    l1, l2 = params["layers"][0], params["layers"][1]
    h2m = block["h2_mask"][..., None].astype(block["h2_feats"].dtype)
    h1m = block["h1_mask"][..., None].astype(block["h1_feats"].dtype)
    # layer 1 on hop-1 nodes: aggregate their hop-2 neighbours
    agg2 = (block["h2_feats"] * h2m).sum(2) / jnp.maximum(h2m.sum(2), 1.0)
    h1 = _combine(l1, block["h1_feats"], agg2, last=False)     # [B, f1, H]
    # layer 1 on seeds: aggregate hop-1 neighbours (raw feats)
    agg1 = (block["h1_feats"] * h1m).sum(1) / jnp.maximum(h1m.sum(1), 1.0)
    h0 = _combine(l1, block["seed_feats"], agg1, last=False)   # [B, H]
    # layer 2 on seeds: aggregate layer-1 hop-1 states
    agg = (h1 * h1m).sum(1) / jnp.maximum(h1m.sum(1), 1.0)
    h = _combine(l2, h0, agg, last=False)
    return h @ params["cls"]


# ---------------------------------------------------------------------------
# batched small graphs (molecule) — graph-level classification
# ---------------------------------------------------------------------------
def sage_molecule(params: dict, cfg: GNNConfig, batch: dict, mi: MeshInfo):
    """node_feats [G, N, F]; edges [G, E, 2] (-1 pad); node_mask [G, N].
    Returns graph logits [G, C] (mean readout)."""
    feats = batch["node_feats"]
    g, n, _ = feats.shape
    src = jnp.maximum(batch["edges"][..., 0], 0)
    dst = jnp.maximum(batch["edges"][..., 1], 0)
    emask = (batch["edges"][..., 0] >= 0).astype(feats.dtype)[..., None]
    nmask = batch["node_mask"][..., None].astype(feats.dtype)
    gi = jnp.arange(g)[:, None]
    deg = jnp.zeros((g, n, 1), feats.dtype).at[gi, dst].add(emask)
    deg = jnp.maximum(deg, 1.0)
    h = feats
    for layer in params["layers"]:
        msgs = h[gi, src] * emask                  # [G, E, H]
        agg = jnp.zeros((g, n, h.shape[-1]), h.dtype).at[gi, dst].add(msgs)
        agg = agg / deg
        h = _combine(layer, h, agg, last=False) * nmask
    readout = (h * nmask).sum(1) / jnp.maximum(nmask.sum(1), 1.0)
    return readout @ params["cls"]


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def gnn_loss(params: dict, cfg: GNNConfig, batch: dict, mi: MeshInfo,
             regime: str):
    if regime == "full_graph":
        logits = sage_full_graph(params, cfg, batch["feats"], batch["edges"],
                                 mi)
        mask = batch.get("train_mask")
        loss = cm.softmax_xent(logits, batch["labels"], mask)
    elif regime == "minibatch":
        logits = sage_minibatch(params, cfg, batch, mi)
        loss = cm.softmax_xent(logits, batch["labels"])
    elif regime == "molecule":
        logits = sage_molecule(params, cfg, batch, mi)
        loss = cm.softmax_xent(logits, batch["labels"])
    else:
        raise ValueError(regime)
    return loss, {"loss": loss}
