"""The LM transformer family: one flexible decoder-only stack covering
deepseek-7b (llama-arch GQA), qwen3-14b (GQA + qk-norm), nemotron-4-340b
(GQA + squared-ReLU FFN), deepseek-v3-671b (MLA + shared/routed MoE + MTP),
qwen3-moe-235b (GQA + MoE).

Structure: pre-RMSNorm blocks, scan-over-layers (+remat), mixed dense/MoE
stacks (first ``n_dense_layers`` dense, rest MoE), vocab tables row-sharded
over 'model' (the paper's table sharding applied to embed/unembed), sequence-
parallel activations between blocks, FSDP('pod','data') × TP('model') weight
sharding.  See DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common as cm
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.common import Boxed, MeshInfo

FSDP = ("pod", "data")


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    attn_type: str = "gqa"              # gqa | mla
    ffn_type: str = "swiglu"            # swiglu | squared_relu
    qk_norm: bool = False
    moe: Optional[moe_mod.MoEConfig] = None
    n_dense_layers: int = 0             # leading dense layers in MoE models
    mtp_depth: int = 0                  # DeepSeek-V3 multi-token prediction
    rope_base: float = 10000.0
    q_chunk: int = 512
    dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 512     # sequence chunking of the CE (0 = off)
    unroll: bool = False      # unroll layer scans (exact cost_analysis; the
    #                           dry-run's --fit-layers uses this on small L)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_moe_layers(self) -> int:
        return self.n_layers - self.n_dense_layers if self.moe else 0

    def gqa_cfg(self) -> attn.GQAConfig:
        return attn.GQAConfig(self.d_model, self.n_heads, self.n_kv_heads,
                              self.head_dim, self.qk_norm, self.rope_base,
                              self.q_chunk)

    def mla_cfg(self) -> attn.MLAConfig:
        return attn.MLAConfig(self.d_model, self.n_heads,
                              rope_base=self.rope_base, q_chunk=self.q_chunk)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _ffn_init(key, cfg: LMConfig, dtype) -> dict:
    ks = cm.keygen(key)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.ffn_type == "swiglu":
        return {
            "w_gate": cm.dense_param(next(ks), d, f, P(FSDP, "model"), dtype),
            "w_up": cm.dense_param(next(ks), d, f, P(FSDP, "model"), dtype),
            "w_down": cm.dense_param(next(ks), f, d, P("model", FSDP), dtype),
        }
    if cfg.ffn_type == "squared_relu":
        return {
            "w_in": cm.dense_param(next(ks), d, f, P(FSDP, "model"), dtype),
            "w_out": cm.dense_param(next(ks), f, d, P("model", FSDP), dtype),
        }
    raise ValueError(cfg.ffn_type)


def _attn_init(key, cfg: LMConfig, dtype) -> dict:
    if cfg.attn_type == "mla":
        return attn.mla_init(key, cfg.mla_cfg(), dtype)
    return attn.gqa_init(key, cfg.gqa_cfg(), dtype)


def _layer_init(key, cfg: LMConfig, use_moe: bool) -> dict:
    dtype = cfg.jdtype
    ks = cm.keygen(key)
    p = {
        "ln1": cm.scale_param(cfg.d_model, P(None), dtype),
        "attn": _attn_init(next(ks), cfg, dtype),
        "ln2": cm.scale_param(cfg.d_model, P(None), dtype),
    }
    if use_moe:
        p["moe"] = moe_mod.moe_init(next(ks), cfg.moe, dtype)
    else:
        p["ffn"] = _ffn_init(next(ks), cfg, dtype)
    return p


def lm_init(key, cfg: LMConfig) -> dict:
    """Returns a Boxed tree (value + PartitionSpec per leaf)."""
    dtype = cfg.jdtype
    ks = cm.keygen(key)
    n_dense = cfg.n_layers - cfg.n_moe_layers
    params: dict = {
        # embed is d-sharded (P(None,'model')), NOT vocab-sharded: a gather
        # over vocab-sharded rows makes XLA materialize full-vocab fp32
        # gradients per device (measured 1.68 GB x many on deepseek-7b);
        # d-sharding keeps lookup and its scatter-add gradient shard-local
        # (§Perf A4)
        "embed": cm.embed_param(next(ks), cfg.vocab, cfg.d_model,
                                P(None, "model"), dtype),
        "final_ln": cm.scale_param(cfg.d_model, P(None), dtype),
        "unembed": cm.dense_param(next(ks), cfg.d_model, cfg.vocab,
                                  P(FSDP, "model"), dtype),
    }
    if n_dense:
        keys = jax.random.split(next(ks), n_dense)
        params["dense_layers"] = cm.stack_specs(
            jax.vmap(lambda k: _layer_init(k, cfg, use_moe=False))(keys))
    if cfg.n_moe_layers:
        keys = jax.random.split(next(ks), cfg.n_moe_layers)
        params["moe_layers"] = cm.stack_specs(
            jax.vmap(lambda k: _layer_init(k, cfg, use_moe=True))(keys))
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": cm.dense_param(next(ks), 2 * cfg.d_model, cfg.d_model,
                                   P(FSDP, None), dtype),
            "ln_h": cm.scale_param(cfg.d_model, P(None), dtype),
            "ln_e": cm.scale_param(cfg.d_model, P(None), dtype),
            "block": _layer_init(next(ks), cfg, use_moe=False),
            "final_ln": cm.scale_param(cfg.d_model, P(None), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _ffn_apply(p: dict, cfg: LMConfig, x, mi: MeshInfo):
    if cfg.ffn_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = mi.shard(h, mi.dp, None, "model")
        return h @ p["w_down"]
    h = cm.squared_relu(x @ p["w_in"])
    h = mi.shard(h, mi.dp, None, "model")
    return h @ p["w_out"]


def _layer_apply(p: dict, cfg: LMConfig, x, mesh, mi: MeshInfo,
                 use_moe: bool):
    """Pre-norm block.  Returns (x, aux)."""
    h = attn_out = None
    a = cm.rms_norm(x, p["ln1"])
    if cfg.attn_type == "mla":
        attn_out = attn.mla_apply(p["attn"], cfg.mla_cfg(), a, mi)
    else:
        attn_out = attn.gqa_apply(p["attn"], cfg.gqa_cfg(), a, mi)
    x = x + attn_out
    x = mi.shard(x, mi.dp, "model", None)       # SP between sublayers
    h = cm.rms_norm(x, p["ln2"])
    if use_moe:
        y, aux, dropped = moe_mod.moe_apply(p["moe"], cfg.moe, h, mesh, mi)
    else:
        y, aux, dropped = _ffn_apply(p["ffn"], cfg, h, mi), 0.0, 0.0
    x = x + y
    x = mi.shard(x, mi.dp, "model", None)
    return x, (jnp.asarray(aux, jnp.float32),
               jnp.asarray(dropped, jnp.float32))


def _scan_stack(stack_params, cfg: LMConfig, x, mesh, mi: MeshInfo,
                use_moe: bool):
    layer = functools.partial(_layer_apply, cfg=cfg, mesh=mesh, mi=mi,
                              use_moe=use_moe)
    fn = (jax.checkpoint(lambda p, x: layer(p, x=x)) if cfg.remat
          else (lambda p, x: layer(p, x=x)))

    def body(carry, lp):
        x = carry
        x, aux = fn(lp, x)
        return x, aux

    n = jax.tree.leaves(stack_params)[0].shape[0]
    x, auxes = jax.lax.scan(body, x, stack_params,
                            unroll=n if cfg.unroll else 1)
    return x, jax.tree.map(jnp.sum, auxes)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------
def lm_backbone(params: dict, cfg: LMConfig, tokens, mesh, mi: MeshInfo):
    """tokens [B, S] -> hidden [B, S, d] (pre-final-norm aux summed)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = mi.shard(x, mi.dp, "model", None)
    aux = (jnp.float32(0), jnp.float32(0))
    if "dense_layers" in params:
        x, a = _scan_stack(params["dense_layers"], cfg, x, mesh, mi, False)
        aux = jax.tree.map(jnp.add, aux, a)
    if "moe_layers" in params:
        x, a = _scan_stack(params["moe_layers"], cfg, x, mesh, mi, True)
        aux = jax.tree.map(jnp.add, aux, a)
    return x, aux


def lm_logits(params: dict, cfg: LMConfig, h):
    h = cm.rms_norm(h, params["final_ln"])
    return h @ params["unembed"]


def _chunked_xent(params, cfg: LMConfig, h, targets, mi: MeshInfo,
                  project=None):
    """CE over sequence chunks: the [B, C, V] logits chunk is the only live
    vocab-sized tensor (full-S logits at 100k+ vocab would dominate HBM)."""
    if project is None:
        project = lambda hx: lm_logits(params, cfg, hx)
    b, s, d = h.shape
    chunk = cfg.loss_chunk
    if chunk <= 0 or s <= chunk:
        return cm.softmax_xent(project(h), targets)
    pad = (-s) % chunk
    mask = jnp.ones((b, s), jnp.float32)
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = (s + pad) // chunk
    hc = jnp.moveaxis(h.reshape(b, n, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, n, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, n, chunk), 1, 0)

    @jax.checkpoint
    def chunk_nll(hx, tx, mx):
        # remat: backward recomputes this chunk's logits instead of the scan
        # stacking fp32 softmax residuals for every chunk (§Perf A2)
        logits = project(hx)
        logits = mi.shard(logits, mi.dp, None, "model")
        return cm.softmax_xent(logits, tx, mx) * jnp.sum(mx)

    def body(carry, xt):
        hx, tx, mx = xt
        # masked SUM of nll per chunk; normalize by token count at the end
        return carry + chunk_nll(hx, tx, mx), None

    tot, _ = jax.lax.scan(body, jnp.float32(0), (hc, tc, mc),
                          unroll=n if cfg.unroll else 1)
    return tot / (b * s)


def lm_loss(params: dict, cfg: LMConfig, batch: dict, mesh,
            mi: MeshInfo):
    """batch: tokens [B, S] int32 (next-token targets derived in-place).
    Returns (loss, metrics)."""
    tokens = batch["tokens"]
    h, (aux, dropped) = lm_backbone(params, cfg, tokens, mesh, mi)
    loss = _chunked_xent(params, cfg, h[:, :-1], tokens[:, 1:], mi)
    metrics = {"xent": loss, "moe_aux": aux, "moe_dropped": dropped}
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_weight * aux
    if cfg.mtp_depth:
        mtp_loss = _mtp_loss(params, cfg, tokens, h, mesh, mi)
        metrics["mtp"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(params: dict, cfg: LMConfig, tokens, h, mesh, mi: MeshInfo):
    """DeepSeek-V3 MTP (depth 1): combine hidden t with embedding of token
    t+1, run one extra block, predict token t+2 with the shared unembed."""
    p = params["mtp"]
    b, s, d = h.shape
    emb_next = jnp.take(params["embed"], tokens[:, 1:], axis=0)
    hh = cm.rms_norm(h[:, :-1], p["ln_h"])
    ee = cm.rms_norm(emb_next, p["ln_e"])
    x = jnp.concatenate([hh, ee], axis=-1) @ p["proj"]
    x = mi.shard(x, mi.dp, None, None)
    x, _ = _layer_apply(p["block"], cfg, x, mesh, mi, use_moe=False)
    x = cm.rms_norm(x, p["final_ln"])
    # predicts t+2; chunked like the main loss
    return _chunked_xent(params, cfg, x[:, :-1], tokens[:, 2:], mi,
                         project=lambda hx: hx @ params["unembed"])


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------
def _layer_decode(p: dict, cfg: LMConfig, x, cache, pos, mesh, mi: MeshInfo,
                  use_moe: bool):
    a = cm.rms_norm(x, p["ln1"])
    if cfg.attn_type == "mla":
        y, new_cache = attn.mla_decode(p["attn"], cfg.mla_cfg(), a, cache,
                                       pos, mi, mesh)
    else:
        y, new_cache = attn.gqa_decode(p["attn"], cfg.gqa_cfg(), a, cache,
                                       pos, mi, mesh)
    x = x + y
    h = cm.rms_norm(x, p["ln2"])
    if use_moe:
        y, _, _ = moe_mod.moe_apply(p["moe"], cfg.moe, h, mesh, mi,
                                    token_spec=P(None, None, None))
    else:
        y = _ffn_apply(p["ffn"], cfg, h, mi)
    return x + y, new_cache


def lm_decode_step(params: dict, cfg: LMConfig, token, pos, caches: dict,
                   mesh, mi: MeshInfo):
    """One-token decode.  token [B] int32; pos [B] int32 current lengths;
    caches: {'dense': stacked cache pytree [Ld, ...], 'moe': [...]}.
    Returns (logits [B, V], new caches)."""
    x = jnp.take(params["embed"], token[:, None], axis=0)
    new_caches = {}
    for kind, key in (("dense", "dense_layers"), ("moe", "moe_layers")):
        if key not in params:
            continue
        use_moe = kind == "moe"

        def body(carry, scanned, use_moe=use_moe):
            x = carry
            lp, cache_l = scanned
            x, new_cache = _layer_decode(lp, cfg, x, cache_l, pos, mesh, mi,
                                         use_moe)
            return x, new_cache

        n = jax.tree.leaves(params[key])[0].shape[0]
        x, new_caches[kind] = jax.lax.scan(body, x,
                                           (params[key], caches[kind]),
                                           unroll=n if cfg.unroll else 1)
    logits = lm_logits(params, cfg, x)[:, 0]
    return logits, new_caches


def make_decode_cache_specs(cfg: LMConfig, batch: int, s_max: int,
                            mi: Optional[MeshInfo] = None):
    """ShapeDtypeStructs + PartitionSpecs for the decode KV cache (the
    dry-run's input stand-ins).  Sequence dim sharded over 'model'; batch
    sharded over the data axes when divisible — leaving batch replicated
    costs ×|dp| cache memory per device (measured 86 GB/device on
    qwen3-14b decode_32k, §Perf A7)."""
    dt = cfg.jdtype
    n_dense = cfg.n_layers - cfg.n_moe_layers
    bspec = None
    if mi is not None and mi.dp and batch % max(mi.axis_size(mi.dp), 1) == 0:
        bspec = mi.dp

    def gqa_entry(n_layers):
        shape_kv = (n_layers, batch, s_max, cfg.n_kv_heads, cfg.head_dim)
        spec = P(None, bspec, "model", None, None)
        return ({"k": jax.ShapeDtypeStruct(shape_kv, dt),
                 "v": jax.ShapeDtypeStruct(shape_kv, dt)},
                {"k": spec, "v": spec})

    def mla_entry(n_layers):
        mcfg = cfg.mla_cfg()
        return ({"ckv": jax.ShapeDtypeStruct(
                    (n_layers, batch, s_max, mcfg.kv_lora), dt),
                 "kr": jax.ShapeDtypeStruct(
                    (n_layers, batch, s_max, mcfg.dh_rope), dt)},
                {"ckv": P(None, bspec, "model", None),
                 "kr": P(None, bspec, "model", None)})

    entry = mla_entry if cfg.attn_type == "mla" else gqa_entry
    shapes, specs = {}, {}
    if n_dense:
        shapes["dense"], specs["dense"] = entry(n_dense)
    if cfg.n_moe_layers:
        shapes["moe"], specs["moe"] = entry(cfg.n_moe_layers)
    return shapes, specs
