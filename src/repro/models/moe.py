"""Mixture-of-Experts FFN with expert parallelism over the 'model' axis.

The dispatch IS the paper's batch-query protocol (DESIGN.md §4): tokens are
keys, experts are shards; each device buckets its local tokens by owning
expert, exchanges them with all_to_all over ICI, answers (runs its local
experts), and routes results back — the same route→query→merge schedule as
core/distributed.lookup_a2a_body, with fixed-capacity buffers and explicit
dropped-token accounting (never silent).

Expert weights: [E, d, f] sharded P('model', fsdp, None) — EP over 'model',
FSDP over the data axes.  Shared experts (DeepSeek-style) are dense SwiGLU
computed locally on each token shard (weights replicated over 'model').
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.compat import shard_map

from repro.core.distributed import route_by_owner
from repro.models import common as cm
from repro.models.common import Boxed, MeshInfo


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                  # per-expert hidden dim
    n_experts: int
    top_k: int
    n_shared: int = 0          # shared experts (always-on), DeepSeek style
    shared_d_ff: Optional[int] = None
    capacity_factor: float = 1.25
    aux_weight: float = 0.001
    norm_topk: bool = True     # renormalize top-k gate weights to sum to 1

    @property
    def shared_ff(self) -> int:
        return self.shared_d_ff or (self.n_shared * self.d_ff)


def moe_init(key, cfg: MoEConfig, dtype=jnp.bfloat16) -> dict:
    ks = cm.keygen(key)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    fsdp = ("pod", "data")
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": Boxed(cm.normal_init(next(ks), (d, e), scale, jnp.float32),
                        P(None, None)),
        "w_gate": Boxed(cm.normal_init(next(ks), (e, d, f), scale, dtype),
                        P("model", fsdp, None)),
        "w_up": Boxed(cm.normal_init(next(ks), (e, d, f), scale, dtype),
                      P("model", fsdp, None)),
        "w_down": Boxed(cm.normal_init(next(ks), (e, f, d),
                                       1.0 / math.sqrt(f), dtype),
                        P("model", None, fsdp)),
    }
    if cfg.n_shared:
        fs = cfg.shared_ff
        p["shared"] = {
            "w_gate": Boxed(cm.normal_init(next(ks), (d, fs), scale, dtype),
                            P(fsdp, None)),
            "w_up": Boxed(cm.normal_init(next(ks), (d, fs), scale, dtype),
                          P(fsdp, None)),
            "w_down": Boxed(cm.normal_init(next(ks), (fs, d),
                                           1.0 / math.sqrt(fs), dtype),
                            P(None, fsdp)),
        }
    return p


def _swiglu(x, wg, wu, wd):
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


def _moe_body(params: dict, x_loc: jnp.ndarray, *, cfg: MoEConfig,
              n_ep: int, axes: tuple, ep_axis: str):
    """shard_map body.  x_loc: [t_loc, d] this device's tokens; expert
    weights arrive as local slices [E_loc, d, f]."""
    t_loc, d = x_loc.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // n_ep

    # ---- route ----
    logits = (x_loc.astype(jnp.float32) @ params["router"])       # [t, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                          # [t, k]
    if cfg.norm_topk:
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e, global mean
    me = jnp.mean(probs, axis=0)                                  # [E]
    ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(
        1.0 / (t_loc * k))
    aux_local = e * jnp.sum(me * ce)
    aux = jax.lax.pmean(aux_local, axes)

    # ---- dispatch (the batch-query fan-out) ----
    cap = max(int(math.ceil(t_loc * k / e * cfg.capacity_factor)), 1)
    owner = topi.reshape(-1).astype(jnp.int32)                    # [t*k]
    r = route_by_owner(owner, e, cap)
    x_rep = jnp.repeat(x_loc, k, axis=0)                          # [t*k, d]
    send = jnp.zeros((e, cap, d), x_loc.dtype)
    send = send.at[r.slot_row, r.slot_col].set(
        jnp.where(r.kept[:, None], x_rep, 0))
    dropped = jax.lax.pmean(r.n_dropped.astype(jnp.float32) / (t_loc * k),
                            axes)

    # [E, cap, d] -> [E_loc, cap * n_ep, d]
    recv = jax.lax.all_to_all(send, ep_axis, 0, 1, tiled=True)

    # ---- local experts ----
    h = jnp.einsum("ecd,edf->ecf", recv, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", recv, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, params["w_down"])

    # ---- route back + combine ----
    back = jax.lax.all_to_all(y, ep_axis, 1, 0, tiled=True)       # [E,cap,d]
    per_slot = back[r.slot_row, r.slot_col]                       # [t*k, d]
    per_slot = jnp.where(r.kept[:, None], per_slot, 0)
    w = topv.reshape(-1)[:, None].astype(per_slot.dtype)
    out = jnp.sum((per_slot * w).reshape(t_loc, k, d), axis=1)

    # ---- shared experts (dense, local tokens) ----
    if cfg.n_shared:
        s = params["shared"]
        out = out + _swiglu(x_loc, s["w_gate"], s["w_up"], s["w_down"])
    return out, aux, dropped


def moe_apply(params: dict, cfg: MoEConfig, x: jnp.ndarray, mesh,
              mi: MeshInfo, token_spec: Optional[P] = None):
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar, dropped_frac scalar).

    ``token_spec`` describes how (B, S) is sharded; default: batch over the
    data axes, sequence over 'model' when divisible (SP), else unsharded."""
    b, s, d = x.shape
    ep_axis = "model"
    n_ep = mi.sizes.get(ep_axis, 1)
    if token_spec is None:
        sp_ok = s % max(n_ep, 1) == 0
        dp_ok = b % max(mi.axis_size(mi.dp), 1) == 0
        token_spec = P(mi.dp if dp_ok else None,
                       ep_axis if sp_ok else None, None)

    pspec = {
        "router": P(None, None),
        "w_gate": P(ep_axis, None, None),
        "w_up": P(ep_axis, None, None),
        "w_down": P(ep_axis, None, None),
    }
    if cfg.n_shared:
        pspec["shared"] = {k: P(None, None) for k in params["shared"]}

    body = functools.partial(_moe_body, cfg=cfg, n_ep=n_ep,
                             axes=tuple(mi.axes), ep_axis=ep_axis)

    def wrapped(pp, xx):
        t = xx.reshape(-1, d)
        y, aux, drop = body(pp, t)
        return y.reshape(xx.shape), aux, drop

    fn = shard_map(wrapped, mesh=mesh,
                   in_specs=(pspec, token_spec),
                   out_specs=(token_spec, P(), P()),
                   check_vma=False)
    return fn(params, x)
