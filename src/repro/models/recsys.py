"""Recsys architectures: DIN, BST, TwoTower retrieval, DeepFM.

All four share the substrate the paper serves: big row-sharded embedding
tables (models/embedding_service.py), EmbeddingBag gather-reduce, small dense
towers.  Batch layout: everything is [B, ...] with B sharded over the data
axes; tables sharded over 'model'.

Inputs (data/synthetic.py generates matching batches):
  DIN     hist_items/hist_cats [B, L] (-1 pad), target_item/target_cat [B],
          dense [B, n_dense], label [B]
  BST     same + positions (sequence transformer over hist+target)
  TwoTower user_id [B], hist_items [B, L], item_id [B], item_cat [B]
          (in-batch sampled softmax)
  DeepFM  sparse_ids [B, F] (one id per field), dense [B, 13], label [B]
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import common as cm
from repro.models import embedding_service as es
from repro.models.common import Boxed, MeshInfo

FSDP = ("pod", "data")


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    arch: str                     # din | bst | two_tower | deepfm
    embed_dim: int
    item_vocab: int = 1_000_000
    cat_vocab: int = 10_000
    user_vocab: int = 1_000_000
    seq_len: int = 0              # user-behaviour history length
    n_dense: int = 13
    n_sparse_fields: int = 0      # deepfm fields
    field_vocab: int = 100_000
    mlp: tuple = ()
    attn_mlp: tuple = ()          # din
    n_blocks: int = 1             # bst
    n_heads: int = 8              # bst
    tower_mlp: tuple = ()         # two_tower
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def _mlp_init(key, dims: tuple, spec_mid=P(None, None), dtype=jnp.float32,
              final_bias: bool = True) -> list:
    ks = cm.keygen(key)
    layers = []
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        layers.append({
            "w": cm.dense_param(next(ks), din, dout, spec_mid, dtype),
            "b": Boxed(jnp.zeros((dout,), dtype), P(None)),
        })
    return layers


def _mlp_apply(layers: list, x, act=jax.nn.relu, final_act: bool = False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i + 1 < len(layers) or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# DIN — target attention over user behaviour sequence
# ---------------------------------------------------------------------------
def din_init(key, cfg: RecsysConfig) -> dict:
    ks = cm.keygen(key)
    d = cfg.embed_dim
    dt = cfg.jdtype
    concat_dim = 4 * (2 * d)      # [e, et, e-et, e*et] over item||cat embeds
    head_in = 2 * d + 2 * d + cfg.n_dense   # pooled + target + dense
    return {
        "item_table": es.table_init(next(ks), es.TableCfg(
            "item", cfg.item_vocab, d), dt),
        "cat_table": es.table_init(next(ks), es.TableCfg(
            "cat", cfg.cat_vocab, d), dt),
        "attn_mlp": _mlp_init(next(ks), (concat_dim,) + cfg.attn_mlp + (1,),
                              dtype=dt),
        "mlp": _mlp_init(next(ks), (head_in,) + cfg.mlp + (1,), dtype=dt),
    }


def din_forward(params: dict, cfg: RecsysConfig, batch: dict,
                mi: MeshInfo) -> jnp.ndarray:
    it, ct = params["item_table"], params["cat_table"]
    hist = jnp.concatenate([
        es.embed_lookup(it, batch["hist_items"], mi),
        es.embed_lookup(ct, batch["hist_cats"], mi)], axis=-1)   # [B, L, 2d]
    target = jnp.concatenate([
        es.embed_lookup(it, batch["target_item"], mi),
        es.embed_lookup(ct, batch["target_cat"], mi)], axis=-1)  # [B, 2d]
    tgt = jnp.broadcast_to(target[:, None], hist.shape)
    feat = jnp.concatenate([hist, tgt, hist - tgt, hist * tgt], axis=-1)
    score = _mlp_apply(params["attn_mlp"], feat, act=jax.nn.sigmoid)[..., 0]
    # DIN does NOT softmax-normalize attention weights (paper §4.3);
    # padded positions are zeroed.
    valid = (batch["hist_items"] >= 0).astype(score.dtype)
    pooled = jnp.einsum("bl,bld->bd", score * valid, hist)       # [B, 2d]
    x = jnp.concatenate([pooled, target, batch["dense"]], axis=-1)
    return _mlp_apply(params["mlp"], x)[..., 0]                  # logits [B]


# ---------------------------------------------------------------------------
# BST — one transformer block over (history + target) item sequence
# ---------------------------------------------------------------------------
def bst_init(key, cfg: RecsysConfig) -> dict:
    ks = cm.keygen(key)
    d = cfg.embed_dim
    dt = cfg.jdtype
    s = cfg.seq_len + 1
    head_in = s * d + cfg.n_dense
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append({
            "wq": cm.dense_param(next(ks), d, d, P(None, "model"), dt),
            "wk": cm.dense_param(next(ks), d, d, P(None, "model"), dt),
            "wv": cm.dense_param(next(ks), d, d, P(None, "model"), dt),
            "wo": cm.dense_param(next(ks), d, d, P("model", None), dt),
            "ln1_g": Boxed(jnp.ones((d,), dt), P(None)),
            "ln1_b": Boxed(jnp.zeros((d,), dt), P(None)),
            "ffn1": cm.dense_param(next(ks), d, 4 * d, P(None, "model"), dt),
            "ffn2": cm.dense_param(next(ks), 4 * d, d, P("model", None), dt),
            "ln2_g": Boxed(jnp.ones((d,), dt), P(None)),
            "ln2_b": Boxed(jnp.zeros((d,), dt), P(None)),
        })
    return {
        "item_table": es.table_init(next(ks), es.TableCfg(
            "item", cfg.item_vocab, d), dt),
        "pos_table": Boxed(cm.normal_init(next(ks), (s, d), 0.02, dt),
                           P(None, None)),
        "blocks": blocks,
        "mlp": _mlp_init(next(ks), (head_in,) + cfg.mlp + (1,), dtype=dt),
    }


def _bst_block(p: dict, x, n_heads: int, mask):
    b, s, d = x.shape
    dh = d // n_heads
    q = (x @ p["wq"]).reshape(b, s, n_heads, dh)
    k = (x @ p["wk"]).reshape(b, s, n_heads, dh)
    v = (x @ p["wv"]).reshape(b, s, n_heads, dh)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    sc = jnp.where(mask[:, None, None, :], sc, -1e30)
    a = jax.nn.softmax(sc.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, s, d)
    x = cm.layer_norm(x + o @ p["wo"], p["ln1_g"], p["ln1_b"])
    h = jax.nn.relu(x @ p["ffn1"]) @ p["ffn2"]
    return cm.layer_norm(x + h, p["ln2_g"], p["ln2_b"])


def bst_forward(params: dict, cfg: RecsysConfig, batch: dict,
                mi: MeshInfo) -> jnp.ndarray:
    it = params["item_table"]
    seq_ids = jnp.concatenate(
        [batch["hist_items"], batch["target_item"][:, None]], axis=1)
    x = es.embed_lookup(it, seq_ids, mi) + params["pos_table"][None]
    mask = seq_ids >= 0
    for blk in params["blocks"]:
        x = _bst_block(blk, x, cfg.n_heads, mask)
    b = x.shape[0]
    flat = x.reshape(b, -1)
    x = jnp.concatenate([flat, batch["dense"]], axis=-1)
    return _mlp_apply(params["mlp"], x)[..., 0]


# ---------------------------------------------------------------------------
# TwoTower — retrieval with in-batch sampled softmax
# ---------------------------------------------------------------------------
def two_tower_init(key, cfg: RecsysConfig) -> dict:
    ks = cm.keygen(key)
    d = cfg.embed_dim
    dt = cfg.jdtype
    user_in = 2 * d + cfg.n_dense
    item_in = 2 * d
    return {
        "user_table": es.table_init(next(ks), es.TableCfg(
            "user", cfg.user_vocab, d), dt),
        "item_table": es.table_init(next(ks), es.TableCfg(
            "item", cfg.item_vocab, d), dt),
        "cat_table": es.table_init(next(ks), es.TableCfg(
            "cat", cfg.cat_vocab, d), dt),
        "user_mlp": _mlp_init(next(ks), (user_in,) + cfg.tower_mlp, dtype=dt),
        "item_mlp": _mlp_init(next(ks), (item_in,) + cfg.tower_mlp, dtype=dt),
    }


def user_tower(params: dict, cfg: RecsysConfig, batch: dict,
               mi: MeshInfo, mesh=None, lookup_impl: str = "xla"
               ) -> jnp.ndarray:
    if lookup_impl == "a2a":
        # the paper's routed batch query as the serving lookup (§Perf C1)
        u = es.embed_lookup_a2a(params["user_table"], batch["user_id"],
                                mesh, mi)
        rows = es.embed_lookup_a2a(params["item_table"],
                                   batch["hist_items"], mesh, mi)
        valid = (batch["hist_items"] >= 0).astype(rows.dtype)
        hist = (rows * valid[..., None]).sum(1) / \
            jnp.maximum(valid.sum(1)[:, None], 1.0)
    elif lookup_impl == "psum16":
        # shard-local partial bag reduce + bf16 psum (§Perf C2)
        u = es.embed_lookup_a2a(params["user_table"], batch["user_id"],
                                mesh, mi)
        hist = es.embed_bag_psum(params["item_table"], batch["hist_items"],
                                 "mean", mesh, mi)
    else:
        u = es.embed_lookup(params["user_table"], batch["user_id"], mi)
        hist = es.embed_bag(params["item_table"], batch["hist_items"], None,
                            "mean", mi)
    x = jnp.concatenate([u, hist, batch["dense"]], axis=-1)
    v = _mlp_apply(params["user_mlp"], x)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def item_tower(params: dict, cfg: RecsysConfig, item_id, item_cat,
               mi: MeshInfo) -> jnp.ndarray:
    e = jnp.concatenate([
        es.embed_lookup(params["item_table"], item_id, mi),
        es.embed_lookup(params["cat_table"], item_cat, mi)], axis=-1)
    v = _mlp_apply(params["item_mlp"], e)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def two_tower_loss(params: dict, cfg: RecsysConfig, batch: dict,
                   mi: MeshInfo):
    """In-batch sampled softmax with logQ correction (Yi et al., RecSys'19)."""
    u = user_tower(params, cfg, batch, mi)                     # [B, D]
    i = item_tower(params, cfg, batch["item_id"], batch["item_cat"], mi)
    logits = (u @ i.T) / 0.05                                  # temperature
    if "logq" in batch:                                        # popularity
        logits = logits - batch["logq"][None, :]
    labels = jnp.arange(u.shape[0])
    return cm.softmax_xent(logits, labels)


def retrieval_scores(params: dict, cfg: RecsysConfig, batch: dict,
                     cand_ids, cand_cats, mi: MeshInfo, top_k: int = 100):
    """1 query (or few) against n_candidates: batched dot, then top-k —
    never a python loop over candidates."""
    u = user_tower(params, cfg, batch, mi)                     # [B, D]
    c = item_tower(params, cfg, cand_ids, cand_cats, mi)       # [N, D]
    c = mi.shard(c, "model", None)
    scores = u @ c.T                                           # [B, N]
    return jax.lax.top_k(scores, top_k)


# ---------------------------------------------------------------------------
# DeepFM — FM branch (fused kernel) + deep MLP, shared embeddings
# ---------------------------------------------------------------------------
def deepfm_init(key, cfg: RecsysConfig) -> dict:
    ks = cm.keygen(key)
    d, f = cfg.embed_dim, cfg.n_sparse_fields
    dt = cfg.jdtype
    deep_in = f * d + cfg.n_dense
    return {
        # one big hash-shared table for all fields (industry practice); the
        # per-field offset keeps fields disjoint.
        "field_table": es.table_init(next(ks), es.TableCfg(
            "fields", cfg.field_vocab * f, d), dt),
        "w1_table": es.table_init(next(ks), es.TableCfg(
            "fields_w1", cfg.field_vocab * f, 1), dt),
        "dense_w1": cm.dense_param(next(ks), cfg.n_dense, 1, P(None, None),
                                   dt),
        "mlp": _mlp_init(next(ks), (deep_in,) + cfg.mlp + (1,), dtype=dt),
        "bias": Boxed(jnp.zeros((), dt), P()),
    }


def deepfm_forward(params: dict, cfg: RecsysConfig, batch: dict,
                   mi: MeshInfo) -> jnp.ndarray:
    f = cfg.n_sparse_fields
    ids = batch["sparse_ids"]                                  # [B, F]
    offset = jnp.arange(f, dtype=ids.dtype) * cfg.field_vocab
    flat_ids = ids + offset[None, :]
    emb = es.embed_lookup(params["field_table"], flat_ids, mi)  # [B, F, D]
    # FM second-order (fused Pallas kernel on TPU, oracle elsewhere)
    fm2 = kops.fm_interaction(emb)                              # [B]
    w1 = es.embed_lookup(params["w1_table"], flat_ids, mi)[..., 0].sum(-1)
    dense1 = (batch["dense"] @ params["dense_w1"])[..., 0]
    deep_in = jnp.concatenate(
        [emb.reshape(emb.shape[0], -1), batch["dense"]], axis=-1)
    deep = _mlp_apply(params["mlp"], deep_in)[..., 0]
    return params["bias"] + w1 + dense1 + fm2.astype(deep.dtype) + deep


# ---------------------------------------------------------------------------
# sparse-embedding training path (§Perf B1)
#
# Differentiating through jnp.take gives a DENSE [V, D] cotangent per table —
# at 10⁸ rows that is tens of GB of pure-zero traffic per step, swamping the
# memory roofline term.  The sparse path gathers rows first, differentiates
# w.r.t. the gathered rows only, and scatter-applies row-wise Adagrad to the
# touched rows (exactly what the paper's Update Subsystem publishes).
# ---------------------------------------------------------------------------
def table_ids(cfg: RecsysConfig, batch: dict) -> dict:
    """-> {row_key: (table_name, ids array)} per arch."""
    if cfg.arch == "din":
        return {
            "hist_items": ("item_table", batch["hist_items"]),
            "target_item": ("item_table", batch["target_item"]),
            "hist_cats": ("cat_table", batch["hist_cats"]),
            "target_cat": ("cat_table", batch["target_cat"]),
        }
    if cfg.arch == "bst":
        seq_ids = jnp.concatenate(
            [batch["hist_items"], batch["target_item"][:, None]], axis=1)
        return {"seq_ids": ("item_table", seq_ids)}
    if cfg.arch == "two_tower":
        return {
            "user_id": ("user_table", batch["user_id"]),
            "hist_items": ("item_table", batch["hist_items"]),
            "item_id": ("item_table", batch["item_id"]),
            "item_cat": ("cat_table", batch["item_cat"]),
        }
    if cfg.arch == "deepfm":
        offset = jnp.arange(cfg.n_sparse_fields,
                            dtype=batch["sparse_ids"].dtype) * cfg.field_vocab
        flat = batch["sparse_ids"] + offset[None, :]
        return {"field_rows": ("field_table", flat),
                "w1_table": ("w1_table", flat)}
    raise ValueError(cfg.arch)


def gather_rows(params: dict, cfg: RecsysConfig, batch: dict,
                mi: MeshInfo) -> dict:
    return {k: es.embed_lookup(params[t], ids, mi)
            for k, (t, ids) in table_ids(cfg, batch).items()}


def _din_forward_rows(params, cfg, batch, rows, mi):
    hist = jnp.concatenate([rows["hist_items"], rows["hist_cats"]], axis=-1)
    target = jnp.concatenate([rows["target_item"], rows["target_cat"]],
                             axis=-1)
    tgt = jnp.broadcast_to(target[:, None], hist.shape)
    feat = jnp.concatenate([hist, tgt, hist - tgt, hist * tgt], axis=-1)
    score = _mlp_apply(params["attn_mlp"], feat, act=jax.nn.sigmoid)[..., 0]
    valid = (batch["hist_items"] >= 0).astype(score.dtype)
    pooled = jnp.einsum("bl,bld->bd", score * valid, hist)
    x = jnp.concatenate([pooled, target, batch["dense"]], axis=-1)
    return _mlp_apply(params["mlp"], x)[..., 0]


def _bst_forward_rows(params, cfg, batch, rows, mi):
    seq_ids = jnp.concatenate(
        [batch["hist_items"], batch["target_item"][:, None]], axis=1)
    x = rows["seq_ids"] + params["pos_table"][None]
    mask = seq_ids >= 0
    for blk in params["blocks"]:
        x = _bst_block(blk, x, cfg.n_heads, mask)
    flat = x.reshape(x.shape[0], -1)
    x = jnp.concatenate([flat, batch["dense"]], axis=-1)
    return _mlp_apply(params["mlp"], x)[..., 0]


def _two_tower_loss_rows(params, cfg, batch, rows, mi):
    valid = (batch["hist_items"] >= 0).astype(rows["hist_items"].dtype)
    hist = (rows["hist_items"] * valid[..., None]).sum(1) / \
        jnp.maximum(valid.sum(1)[:, None], 1.0)
    xu = jnp.concatenate([rows["user_id"], hist, batch["dense"]], axis=-1)
    u = _mlp_apply(params["user_mlp"], xu)
    u = u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)
    xi = jnp.concatenate([rows["item_id"], rows["item_cat"]], axis=-1)
    i = _mlp_apply(params["item_mlp"], xi)
    i = i / jnp.maximum(jnp.linalg.norm(i, axis=-1, keepdims=True), 1e-6)
    logits = mi.shard((u @ i.T) / 0.05, mi.dp, "model")
    return cm.softmax_xent(logits, jnp.arange(u.shape[0]))


def _deepfm_forward_rows(params, cfg, batch, rows, mi):
    from repro.kernels import ops as kops
    emb = rows["field_rows"]
    fm2 = kops.fm_interaction(emb)
    w1 = rows["w1_table"][..., 0].sum(-1)
    dense1 = (batch["dense"] @ params["dense_w1"])[..., 0]
    deep_in = jnp.concatenate(
        [emb.reshape(emb.shape[0], -1), batch["dense"]], axis=-1)
    deep = _mlp_apply(params["mlp"], deep_in)[..., 0]
    return params["bias"] + w1 + dense1 + fm2.astype(deep.dtype) + deep


FORWARD_ROWS = {"din": _din_forward_rows, "bst": _bst_forward_rows,
                "deepfm": _deepfm_forward_rows}


def recsys_loss_rows(params_dense: dict, cfg: RecsysConfig, batch: dict,
                     rows: dict, mi: MeshInfo):
    if cfg.arch == "two_tower":
        loss = _two_tower_loss_rows(params_dense, cfg, batch, rows, mi)
        return loss, {"loss": loss}
    logits = FORWARD_ROWS[cfg.arch](params_dense, cfg, batch, rows, mi)
    loss = cm.bce_with_logits(logits, batch["label"])
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# shared entry points
# ---------------------------------------------------------------------------
FORWARD = {"din": din_forward, "bst": bst_forward, "deepfm": deepfm_forward}


def recsys_init(key, cfg: RecsysConfig) -> dict:
    return {"din": din_init, "bst": bst_init, "two_tower": two_tower_init,
            "deepfm": deepfm_init}[cfg.arch](key, cfg)


def recsys_loss(params: dict, cfg: RecsysConfig, batch: dict, mi: MeshInfo):
    if cfg.arch == "two_tower":
        loss = two_tower_loss(params, cfg, batch, mi)
        return loss, {"loss": loss}
    logits = FORWARD[cfg.arch](params, cfg, batch, mi)
    loss = cm.bce_with_logits(logits, batch["label"])
    return loss, {"loss": loss}


def recsys_score(params: dict, cfg: RecsysConfig, batch: dict, mi: MeshInfo,
                 mesh=None, lookup_impl: str = "xla"):
    """Serving: CTR probability (pointwise archs) — the paper's T4 workload."""
    if cfg.arch == "two_tower":
        return user_tower(params, cfg, batch, mi, mesh, lookup_impl)
    return jax.nn.sigmoid(FORWARD[cfg.arch](params, cfg, batch, mi))
