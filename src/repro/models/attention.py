"""Attention blocks: GQA (optionally qk-norm) and MLA (DeepSeek-V3), with
query-chunked online-softmax for prefill/train (O(S) activation memory — no
S×S score tensor ever materializes) and KV-cache decode whose cache is
sequence-sharded over the 'model' axis (flash-decoding-on-ICI: the softmax
reduction over the sharded KV axis becomes an all-reduce inserted by SPMD).

Shapes:  x [B, S, d];  GQA cache {k,v: [B, Smax, Hkv, dh]};
         MLA cache {ckv: [B, Smax, kv_lora], kr: [B, Smax, dh_rope]}.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common as cm
from repro.models.common import Boxed, MeshInfo


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GQAConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_base: float = 10000.0
    q_chunk: int = 512


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora: int = 1536
    kv_lora: int = 512
    dh_nope: int = 128
    dh_rope: int = 64
    dv: int = 128
    rope_base: float = 10000.0
    q_chunk: int = 512


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def gqa_init(key, cfg: GQAConfig, dtype=jnp.bfloat16) -> dict:
    ks = cm.keygen(key)
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    fsdp = ("pod", "data")
    p = {
        "wq": cm.dense_param(next(ks), d, h * dh, P(fsdp, "model"), dtype),
        "wk": cm.dense_param(next(ks), d, kv * dh, P(fsdp, "model"), dtype),
        "wv": cm.dense_param(next(ks), d, kv * dh, P(fsdp, "model"), dtype),
        "wo": cm.dense_param(next(ks), h * dh, d, P("model", fsdp), dtype),
    }
    if cfg.qk_norm:
        p["q_gamma"] = cm.scale_param(dh, P(None), dtype)
        p["k_gamma"] = cm.scale_param(dh, P(None), dtype)
    return p


def mla_init(key, cfg: MLAConfig, dtype=jnp.bfloat16) -> dict:
    ks = cm.keygen(key)
    d, h = cfg.d_model, cfg.n_heads
    fsdp = ("pod", "data")
    return {
        "w_dq": cm.dense_param(next(ks), d, cfg.q_lora, P(fsdp, None), dtype),
        "q_gamma": cm.scale_param(cfg.q_lora, P(None), dtype),
        "w_uq": cm.dense_param(next(ks), cfg.q_lora,
                               h * (cfg.dh_nope + cfg.dh_rope),
                               P(fsdp, "model"), dtype),
        "w_dkv": cm.dense_param(next(ks), d, cfg.kv_lora, P(fsdp, None),
                                dtype),
        "kv_gamma": cm.scale_param(cfg.kv_lora, P(None), dtype),
        "w_uk": cm.dense_param(next(ks), cfg.kv_lora, h * cfg.dh_nope,
                               P(fsdp, "model"), dtype),
        "w_uv": cm.dense_param(next(ks), cfg.kv_lora, h * cfg.dv,
                               P(fsdp, "model"), dtype),
        "w_kr": cm.dense_param(next(ks), d, cfg.dh_rope, P(fsdp, None),
                               dtype),
        "wo": cm.dense_param(next(ks), h * cfg.dv, d, P("model", fsdp),
                             dtype),
    }


# ---------------------------------------------------------------------------
# chunked causal attention core
# ---------------------------------------------------------------------------
def _chunked_attention(q, k, v, *, q_chunk: int, causal: bool,
                       q_offset: int = 0, mi: Optional[MeshInfo] = None):
    """q [B, Sq, Hkv, G, dh]; k [B, Sk, Hkv, dh]; v [B, Sk, Hkv, dv]
    -> [B, Sq, Hkv, G, dv].  Scans over query chunks so the live score
    tensor is [B, Hkv, G, q_chunk, Sk]."""
    b, sq, hkv, g, dh = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    sq_orig = sq
    pad = (-sq) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        sq = sq + pad
    n_chunks = sq // q_chunk
    qc = q.reshape(b, n_chunks, q_chunk, hkv, g, dh)
    qc = jnp.moveaxis(qc, 1, 0)                     # [C, B, qc, Hkv, G, dh]

    kpos = jnp.arange(sk)

    @jax.checkpoint
    def one_chunk(ci, qi):
        # remat per q-chunk: without it the chunk scan stacks every chunk's
        # fp32 softmax residuals for backward — the full S×S score tensor the
        # chunking exists to avoid (§Perf A3)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qi.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        if causal:
            qpos = q_offset + ci * q_chunk + jnp.arange(q_chunk)
            mask = kpos[None, :] <= qpos[:, None]
            s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
        return o

    if n_chunks == 1:
        out = one_chunk(0, qc[0])[None]
    else:
        out = jax.lax.map(lambda args: one_chunk(*args),
                          (jnp.arange(n_chunks), qc))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hkv, g, dv)
    return out[:, :sq_orig]


# ---------------------------------------------------------------------------
# GQA apply — train/prefill
# ---------------------------------------------------------------------------
def gqa_apply(params: dict, cfg: GQAConfig, x: jnp.ndarray,
              mi: MeshInfo, positions: Optional[jnp.ndarray] = None,
              return_cache: bool = False):
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    if positions is None:
        positions = jnp.arange(s)[None, :]

    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, kv, dh)
    v = (x @ params["wv"]).reshape(b, s, kv, dh)
    q = mi.shard(q, mi.dp, None, "model", None)
    k = mi.shard(k, mi.dp, None, "model", None)
    v = mi.shard(v, mi.dp, None, "model", None)
    if cfg.qk_norm:
        q = cm.rms_norm(q, params["q_gamma"])
        k = cm.rms_norm(k, params["k_gamma"])
    cos, sin = cm.rope_angles(positions, dh, cfg.rope_base)
    q = cm.apply_rope(q, cos[:, :, None], sin[:, :, None])
    k = cm.apply_rope(k, cos[:, :, None], sin[:, :, None])

    qg = q.reshape(b, s, kv, g, dh)
    out = _chunked_attention(qg, k, v, q_chunk=min(cfg.q_chunk, s),
                             causal=True, mi=mi)
    out = out.reshape(b, s, h * dh)
    y = out @ params["wo"]
    if return_cache:
        return y, {"k": k, "v": v}
    return y


def _flash_decode_body(qg, k_c, v_c, k_new, v_new, pos, *, axis: str,
                       smax: int, n_shards: int):
    """shard_map body: cache S-sharded over ``axis``; update lands only in
    the owning shard; softmax combines with tiny psums (flash-decoding on
    ICI).  qg [B, kv, g, dh] replicated; k_c/v_c local [B, S_loc, kv, dh]."""
    b = qg.shape[0]
    s_loc = smax // n_shards
    i = jax.lax.axis_index(axis)
    base = i * s_loc
    li = pos - base
    inrange = (li >= 0) & (li < s_loc)
    li_c = jnp.clip(li, 0, s_loc - 1)
    if b == 1:
        # long-context single-request: dynamic-update-slice keeps the update
        # in-place (batched scatter at B=1 made XLA copy the cache; §Perf A8)
        # out-of-range shards re-write the existing row (no full-array select)
        start = (0, li_c[0], 0, 0)
        kv_, dh_ = k_c.shape[2], k_c.shape[3]
        cur_k = jax.lax.dynamic_slice(k_c, start, (1, 1, kv_, dh_))
        cur_v = jax.lax.dynamic_slice(v_c, start, (1, 1, kv_, v_c.shape[3]))
        upd_k = jnp.where(inrange[0], k_new[:, None].astype(k_c.dtype),
                          cur_k)
        upd_v = jnp.where(inrange[0], v_new[:, None].astype(v_c.dtype),
                          cur_v)
        k_c = jax.lax.dynamic_update_slice(k_c, upd_k, start)
        v_c = jax.lax.dynamic_update_slice(v_c, upd_v, start)
    else:
        bidx = jnp.arange(b)
        cur_k = k_c[bidx, li_c]
        cur_v = v_c[bidx, li_c]
        sel = inrange[:, None, None]
        k_c = k_c.at[bidx, li_c].set(jnp.where(sel, k_new, cur_k))
        v_c = v_c.at[bidx, li_c].set(jnp.where(sel, v_new, cur_v))

    dh = qg.shape[-1]
    # keep the cache in bf16; accumulate in f32 (upcasting k_c materializes
    # an f32 copy of the whole local cache — measured 86 GB/device on
    # qwen3-14b decode_32k, §Perf A6)
    s = jnp.einsum("bhgd,bkhd->bhgk", (qg * (1.0 / dh ** 0.5)).astype(
        k_c.dtype), k_c, preferred_element_type=jnp.float32)
    kpos = base + jnp.arange(s_loc)
    mask = kpos[None, :] <= pos[:, None]
    s = jnp.where(mask[:, None, None], s, -1e30)
    # online-softmax partials + cross-shard combine (bytes ~ B·H·dh, tiny)
    m_loc = jnp.max(s, axis=-1)
    m = jax.lax.pmax(m_loc, axis)
    e = jnp.exp(s - m[..., None])
    l_loc = jnp.sum(e, axis=-1)
    num_loc = jnp.einsum("bhgk,bkhd->bhgd", e.astype(v_c.dtype), v_c)
    l = jax.lax.psum(l_loc, axis)
    num = jax.lax.psum(num_loc.astype(jnp.float32), axis)
    o = num / jnp.maximum(l, 1e-30)[..., None]
    return o.astype(v_c.dtype), k_c, v_c


def _sharded_cache_attn(mesh, mi: MeshInfo, qg, cache: dict, k_new, v_new,
                        pos):
    """Dispatch to the shard_map flash-decode when the cache can be
    S-sharded over 'model'; plain einsum path otherwise."""
    from repro.core.compat import shard_map
    b, smax = cache["k"].shape[0], cache["k"].shape[1]
    n_shards = mi.sizes.get("model", 1)
    dp = mi.dp
    bspec = dp if (dp and b % max(mi.axis_size(dp), 1) == 0) else None
    if n_shards <= 1 or smax % n_shards or mesh is None:
        # fallback: full-cache path (single device / indivisible S)
        bidx = jnp.arange(b)
        k_c = cache["k"].at[bidx, pos].set(k_new)
        v_c = cache["v"].at[bidx, pos].set(v_new)
        s = jnp.einsum("bhgd,bkhd->bhgk",
                       (qg * (1.0 / qg.shape[-1] ** 0.5)).astype(k_c.dtype),
                       k_c, preferred_element_type=jnp.float32)
        mask = jnp.arange(smax)[None, :] <= pos[:, None]
        s = jnp.where(mask[:, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_c.dtype), v_c)
        return o, k_c, v_c
    body = functools.partial(_flash_decode_body, axis="model", smax=smax,
                             n_shards=n_shards)
    cache_spec = P(bspec, "model", None, None)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec), cache_spec, cache_spec, P(bspec), P(bspec),
                  P(bspec)),
        out_specs=(P(bspec), cache_spec, cache_spec),
        check_vma=False)
    return fn(qg, cache["k"], cache["v"], k_new, v_new, pos)


def gqa_decode(params: dict, cfg: GQAConfig, x: jnp.ndarray, cache: dict,
               pos: jnp.ndarray, mi: MeshInfo, mesh=None):
    """One-token decode.  x [B, 1, d]; cache k/v [B, Smax, Hkv, dh] sharded
    P(dp, 'model', None, None): scatter-update + flash-decoding inside
    shard_map (§Perf A5 — the pjit path all-gathered the cache).
    ``pos`` [B] int32 current lengths.  Returns (y [B,1,d], new_cache)."""
    b, _, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv

    q = (x @ params["wq"]).reshape(b, 1, h, dh)
    k_new = (x @ params["wk"]).reshape(b, 1, kv, dh)
    v_new = (x @ params["wv"]).reshape(b, 1, kv, dh)
    if cfg.qk_norm:
        q = cm.rms_norm(q, params["q_gamma"])
        k_new = cm.rms_norm(k_new, params["k_gamma"])
    cos, sin = cm.rope_angles(pos[:, None], dh, cfg.rope_base)
    q = cm.apply_rope(q, cos[:, :, None], sin[:, :, None])
    k_new = cm.apply_rope(k_new, cos[:, :, None], sin[:, :, None])

    qg = q.reshape(b, kv, g, dh)
    o, k_c, v_c = _sharded_cache_attn(mesh, mi, qg, cache, k_new[:, 0],
                                      v_new[:, 0], pos)
    y = o.reshape(b, 1, h * dh) @ params["wo"]
    return y, {"k": k_c, "v": v_c}


# ---------------------------------------------------------------------------
# MLA apply — train/prefill and decode (latent cache)
# ---------------------------------------------------------------------------
def _mla_qkv(params, cfg: MLAConfig, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = cm.rms_norm(x @ params["w_dq"], params["q_gamma"])
    q = (cq @ params["w_uq"]).reshape(b, s, h, cfg.dh_nope + cfg.dh_rope)
    q_nope, q_rope = jnp.split(q, [cfg.dh_nope], axis=-1)
    ckv = cm.rms_norm(x @ params["w_dkv"], params["kv_gamma"])
    kr = x @ params["w_kr"]                                   # [B,S,dh_rope]
    cos, sin = cm.rope_angles(positions, cfg.dh_rope, cfg.rope_base)
    q_rope = cm.apply_rope(q_rope, cos[:, :, None], sin[:, :, None])
    kr = cm.apply_rope(kr[:, :, None], cos[:, :, None],
                       sin[:, :, None])[:, :, 0]
    return q_nope, q_rope, ckv, kr


def _mla_expand_kv(params, cfg: MLAConfig, ckv, kr):
    b, s, _ = ckv.shape
    h = cfg.n_heads
    k_nope = (ckv @ params["w_uk"]).reshape(b, s, h, cfg.dh_nope)
    v = (ckv @ params["w_uv"]).reshape(b, s, h, cfg.dv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None], (b, s, h, cfg.dh_rope))],
        axis=-1)
    return k, v


def mla_apply(params: dict, cfg: MLAConfig, x: jnp.ndarray, mi: MeshInfo,
              positions: Optional[jnp.ndarray] = None,
              return_cache: bool = False):
    b, s, d = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q_nope, q_rope, ckv, kr = _mla_qkv(params, cfg, x, positions)
    k, v = _mla_expand_kv(params, cfg, ckv, kr)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = mi.shard(q, mi.dp, None, "model", None)
    k = mi.shard(k, mi.dp, None, "model", None)
    # MHA == GQA with G=1
    out = _chunked_attention(
        q.reshape(b, s, h, 1, cfg.dh_nope + cfg.dh_rope), k, v,
        q_chunk=min(cfg.q_chunk, s), causal=True, mi=mi)
    out = out.reshape(b, s, h * cfg.dv)
    y = out @ params["wo"]
    if return_cache:
        return y, {"ckv": ckv, "kr": kr}
    return y


def _mla_flash_body(q_abs, q_rope, ckv_c, kr_c, ckv_new, kr_new, pos, *,
                    axis: str, smax: int, n_shards: int, scale: float):
    """Latent-cache flash-decode: score/context both live in the kv_lora
    latent space, combined across S-shards with tiny psums."""
    b = q_abs.shape[0]
    s_loc = smax // n_shards
    i = jax.lax.axis_index(axis)
    base = i * s_loc
    li = pos - base
    inrange = (li >= 0) & (li < s_loc)
    li_c = jnp.clip(li, 0, s_loc - 1)
    bidx = jnp.arange(b)
    sel = inrange[:, None]
    ckv_c = ckv_c.at[bidx, li_c].set(
        jnp.where(sel, ckv_new, ckv_c[bidx, li_c]))
    kr_c = kr_c.at[bidx, li_c].set(
        jnp.where(sel, kr_new, kr_c[bidx, li_c]))

    s_nope = jnp.einsum("bhl,bkl->bhk", q_abs.astype(ckv_c.dtype), ckv_c,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhr,bkr->bhk", q_rope.astype(kr_c.dtype), kr_c,
                        preferred_element_type=jnp.float32)
    s = (s_nope + s_rope) * scale
    mask = (base + jnp.arange(s_loc))[None, :] <= pos[:, None]
    s = jnp.where(mask[:, None], s, -1e30)
    m = jax.lax.pmax(jnp.max(s, axis=-1), axis)
    e = jnp.exp(s - m[..., None])
    l = jax.lax.psum(jnp.sum(e, axis=-1), axis)
    num = jax.lax.psum(
        jnp.einsum("bhk,bkl->bhl", e.astype(ckv_c.dtype), ckv_c,
                   preferred_element_type=jnp.float32), axis)
    ctx = num / jnp.maximum(l, 1e-30)[..., None]
    return ctx, ckv_c, kr_c


def mla_decode(params: dict, cfg: MLAConfig, x: jnp.ndarray, cache: dict,
               pos: jnp.ndarray, mi: MeshInfo, mesh=None):
    """Latent-cache decode: cache stores (ckv [B,Smax,kv_lora], kr
    [B,Smax,dh_rope]) — 576 B/token/layer at bf16 instead of h*(dh+dv).
    The nope-score uses the absorbed form q_nope·W_uk^T·ckv so the per-head
    K never materializes for the whole cache; S-sharded via shard_map
    (§Perf A5)."""
    from repro.core.compat import shard_map
    b, _, d = x.shape
    h = cfg.n_heads
    smax = cache["ckv"].shape[1]
    q_nope, q_rope, ckv_new, kr_new = _mla_qkv(params, cfg, x, pos[:, None])

    # absorbed attention: score = q_nope^T W_uk ckv + q_rope^T kr
    w_uk = params["w_uk"].reshape(cfg.kv_lora, h, cfg.dh_nope)
    q_abs = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))              # [B,H,kv_lora]
    scale = (cfg.dh_nope + cfg.dh_rope) ** -0.5
    qr = q_rope[:, 0].astype(jnp.float32)

    n_shards = mi.sizes.get("model", 1)
    dp = mi.dp
    bspec = dp if (dp and b % max(mi.axis_size(dp), 1) == 0) else None
    if n_shards > 1 and smax % n_shards == 0 and mesh is not None:
        body = functools.partial(_mla_flash_body, axis="model", smax=smax,
                                 n_shards=n_shards, scale=scale)
        cspec = P(bspec, "model", None)
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(bspec), P(bspec), cspec, cspec,
                                 P(bspec), P(bspec), P(bspec)),
                       out_specs=(P(bspec), cspec, cspec),
                       check_vma=False)
        ctx, ckv_c, kr_c = fn(q_abs, qr, cache["ckv"], cache["kr"],
                              ckv_new[:, 0], kr_new[:, 0], pos)
    else:
        bidx = jnp.arange(b)
        ckv_c = cache["ckv"].at[bidx, pos].set(ckv_new[:, 0])
        kr_c = cache["kr"].at[bidx, pos].set(kr_new[:, 0])
        s = (jnp.einsum("bhl,bkl->bhk", q_abs.astype(ckv_c.dtype), ckv_c,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bhr,bkr->bhk", qr.astype(kr_c.dtype), kr_c,
                          preferred_element_type=jnp.float32)) * scale
        mask = jnp.arange(smax)[None, :] <= pos[:, None]
        s = jnp.where(mask[:, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhk,bkl->bhl", p.astype(ckv_c.dtype), ckv_c,
                         preferred_element_type=jnp.float32)

    w_uv = params["w_uv"].reshape(cfg.kv_lora, h, cfg.dv)
    o = jnp.einsum("bhl,lhd->bhd", ctx, w_uv.astype(jnp.float32))
    y = o.reshape(b, 1, h * cfg.dv).astype(x.dtype) @ params["wo"]
    new_cache = {"ckv": ckv_c, "kr": kr_c}
    return y, new_cache
