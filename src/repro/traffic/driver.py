"""Open-loop replay of a traffic schedule against a ``QueryServer``.

Closed loops lie about overload: when the server slows down, a
fire-wait-fire client slows its own offered rate and the measured p99
flatters the system.  ``OpenLoopDriver`` fires each
:class:`~repro.traffic.loadgen.RequestEvent` at its scheduled offer time
regardless of how the server is doing — sheds and deadline misses land
as recorded outcomes, not reduced load — which is what makes the
flash-crowd numbers honest.

The driver owns a :class:`TrafficStats` silo (offered / completed / shed
/ failed, per-class latency reservoirs, SLO attainment, dispatcher lag)
exposed through the obs registry by ``obs.bridge.bridge_traffic_stats``,
keeps every per-request :class:`Sample` for burst-window percentile
analysis, and renders a machine-readable SLO report per run
(:func:`slo_report`).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.api.types import QoSClass, QueryRequest
from repro.serve.scheduler import ShedError
from repro.traffic.loadgen import (RequestEvent, TrafficPattern,
                                   burst_windows, generate_schedule)

__all__ = [
    "ClassTraffic", "OpenLoopDriver", "Sample", "TrafficSnapshot",
    "TrafficStats", "burst_p99_ms", "slo_report",
]

_RESERVOIR = 4096


def _percentile_ms(samples_s: Sequence[float], q: float) -> float:
    if not samples_s:
        return float("nan")
    return float(np.percentile(np.asarray(samples_s), q) * 1e3)


# ---------------------------------------------------------------------------
# stats silo
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ClassTraffic:
    """One QoS class's slice of a :class:`TrafficSnapshot`."""

    offered: int = 0
    completed: int = 0
    shed: int = 0
    failed: int = 0
    slo_hits: int = 0
    slo_misses: int = 0
    attainment: float = float("nan")
    p50_ms: float = float("nan")
    p99_ms: float = float("nan")


@dataclasses.dataclass
class TrafficSnapshot:
    """Point-in-time totals for one load-generator run.

    ``attainment`` counts sheds and failures as SLO misses (the user saw
    nothing, which is worse than seeing it late); budget-less requests
    (PREFETCH by default) hit their SLO by completing at all.
    ``dispatch_lag_ms`` is the worst lateness of any fire relative to its
    scheduled offer time — the open-loop fidelity check."""

    offered: int = 0
    completed: int = 0
    shed: int = 0
    failed: int = 0
    slo_hits: int = 0
    slo_misses: int = 0
    attainment: float = float("nan")
    offered_rps: float = 0.0
    dispatch_lag_ms: float = 0.0
    p50_ms: float = float("nan")
    p99_ms: float = float("nan")
    per_class: dict = dataclasses.field(default_factory=dict)


class TrafficStats:
    """Thread-safe accumulator shared by the dispatcher and reapers."""

    def __init__(self):
        self._lock = threading.Lock()
        self._t_start: Optional[float] = None
        self._t_last: Optional[float] = None
        self._dispatch_lag_s = 0.0
        self._counts = {q: ClassTraffic() for q in QoSClass}
        self._lat: dict[QoSClass, list[float]] = {q: [] for q in QoSClass}

    # -- recording ------------------------------------------------------
    def on_offer(self, qos: QoSClass, lag_s: float, now: float) -> None:
        with self._lock:
            if self._t_start is None:
                self._t_start = now
            self._t_last = now
            c = self._counts[qos]
            c.offered += 1
            if lag_s > self._dispatch_lag_s:
                self._dispatch_lag_s = lag_s

    def on_outcome(self, qos: QoSClass, outcome: str,
                   latency_s: float, slo_met: bool) -> None:
        with self._lock:
            c = self._counts[qos]
            if outcome == "completed":
                c.completed += 1
                lat = self._lat[qos]
                if len(lat) < _RESERVOIR:
                    lat.append(latency_s)
            elif outcome == "shed":
                c.shed += 1
            else:
                c.failed += 1
            if slo_met:
                c.slo_hits += 1
            else:
                c.slo_misses += 1

    # -- reading --------------------------------------------------------
    def snapshot(self) -> TrafficSnapshot:
        with self._lock:
            snap = TrafficSnapshot()
            all_lat: list[float] = []
            for q in QoSClass:
                c = self._counts[q]
                lat = self._lat[q]
                cls = ClassTraffic(
                    offered=c.offered, completed=c.completed, shed=c.shed,
                    failed=c.failed, slo_hits=c.slo_hits,
                    slo_misses=c.slo_misses,
                    attainment=(c.slo_hits / c.offered
                                if c.offered else float("nan")),
                    p50_ms=_percentile_ms(lat, 50.0),
                    p99_ms=_percentile_ms(lat, 99.0))
                snap.per_class[q.name] = cls
                snap.offered += c.offered
                snap.completed += c.completed
                snap.shed += c.shed
                snap.failed += c.failed
                snap.slo_hits += c.slo_hits
                snap.slo_misses += c.slo_misses
                all_lat.extend(lat)
            snap.attainment = (snap.slo_hits / snap.offered
                               if snap.offered else float("nan"))
            wall = ((self._t_last - self._t_start)
                    if self._t_start is not None and self._t_last is not None
                    else 0.0)
            snap.offered_rps = snap.offered / wall if wall > 0 else 0.0
            snap.dispatch_lag_ms = self._dispatch_lag_s * 1e3
            snap.p50_ms = _percentile_ms(all_lat, 50.0)
            snap.p99_ms = _percentile_ms(all_lat, 99.0)
            return snap


# ---------------------------------------------------------------------------
# per-request record
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Sample:
    """One request's fate, keyed by its *scheduled* offer time so burst
    windows can be sliced out of the run afterwards."""

    t_s: float                 # scheduled offer time (pattern clock)
    qos: QoSClass
    outcome: str               # "completed" | "shed" | "failed"
    latency_s: float           # NaN unless completed
    budget_s: Optional[float]

    @property
    def slo_met(self) -> bool:
        if self.outcome != "completed":
            return False
        return self.budget_s is None or self.latency_s <= self.budget_s


def burst_p99_ms(samples: Sequence[Sample],
                 windows: Sequence[tuple[float, float]],
                 qos: QoSClass = QoSClass.RANKING,
                 ceiling_s: float = 1.0) -> float:
    """Goodput-aware p99 (ms) over requests *offered during* the burst
    windows: completions count at their measured latency, a shed or
    failed request counts at ``ceiling_s`` (a penalty well above any
    plausible completion) — shedding everything must not look like a
    latency win, and configs that complete late must still be
    distinguishable from each other below the ceiling."""
    lats = []
    for s in samples:
        if s.qos is not qos:
            continue
        if not any(lo <= s.t_s < hi for lo, hi in windows):
            continue
        lats.append(min(s.latency_s, ceiling_s)
                    if s.outcome == "completed" else ceiling_s)
    return _percentile_ms(lats, 99.0)


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------
class OpenLoopDriver:
    """Replays a schedule against a live server at wall-clock fidelity.

    One dispatcher thread walks the (time-sorted) schedule, sleeping until
    each event's offer time and submitting asynchronously; ``reapers``
    worker threads collect ticket results so a slow tail never blocks the
    dispatcher.  ``time_scale`` stretches (>1) or compresses (<1) the
    schedule clock — smoke runs replay a long pattern fast."""

    def __init__(self, server, pattern: TrafficPattern, *,
                 keys: Optional[dict[str, np.ndarray]] = None,
                 stats: Optional[TrafficStats] = None,
                 schedule: Optional[list[RequestEvent]] = None,
                 time_scale: float = 1.0,
                 reapers: int = 4,
                 result_timeout_s: float = 10.0):
        if not time_scale > 0:
            raise ValueError(f"time_scale must be > 0, got {time_scale}")
        if reapers < 1:
            raise ValueError(f"reapers must be >= 1, got {reapers}")
        self.server = server
        self.pattern = pattern
        self.keys = keys or {}
        self.stats = stats or TrafficStats()
        self.schedule = (schedule if schedule is not None
                         else generate_schedule(pattern))
        self.time_scale = time_scale
        self.reapers = reapers
        self.result_timeout_s = result_timeout_s
        self.samples: list[Sample] = []
        self._samples_lock = threading.Lock()

    # ------------------------------------------------------------------
    def _event_tables(self, ev: RequestEvent) -> dict[str, np.ndarray]:
        """Map zipfian ranks to actual table keys — identity (rank == key)
        when no key universe was provided."""
        out = {}
        for name, ranks in ev.ranks.items():
            universe = self.keys.get(name)
            if universe is None:
                out[name] = ranks.astype(np.uint64)
            else:
                out[name] = np.asarray(universe)[ranks % len(universe)]
        return out

    def _record(self, ev: RequestEvent, outcome: str,
                latency_s: float) -> None:
        sample = Sample(t_s=ev.t_s, qos=ev.qos, outcome=outcome,
                        latency_s=latency_s, budget_s=ev.budget_s)
        with self._samples_lock:
            self.samples.append(sample)
        self.stats.on_outcome(ev.qos, outcome, latency_s, sample.slo_met)

    def _reap(self, pending: "queue.Queue") -> None:
        while True:
            item = pending.get()
            if item is None:
                return
            ev, ticket, t_submit = item
            try:
                resp = ticket.result(self.result_timeout_s)
            except ShedError:
                self._record(ev, "shed", float("nan"))
            except Exception:
                self._record(ev, "failed", float("nan"))
            else:
                # the server's own submit->scatter measurement: reapers
                # drain a FIFO of tickets that settle out of order, so
                # wall clock here would charge one slow ticket's wait to
                # every fast ticket queued behind it
                lat = getattr(resp, "latency_s", None)
                self._record(ev, "completed",
                             lat if lat is not None
                             else time.monotonic() - t_submit)

    def run(self) -> TrafficSnapshot:
        """Replay the full schedule; returns the final snapshot (the
        per-request :attr:`samples` stay on the driver)."""
        pending: "queue.Queue" = queue.Queue()
        workers = [threading.Thread(target=self._reap, args=(pending,),
                                    name=f"traffic-reaper-{i}", daemon=True)
                   for i in range(self.reapers)]
        for w in workers:
            w.start()
        t0 = time.monotonic()
        try:
            for ev in self.schedule:
                due = t0 + ev.t_s * self.time_scale
                delay = due - time.monotonic()
                if delay > 0:
                    time.sleep(delay)
                now = time.monotonic()
                self.stats.on_offer(ev.qos, max(0.0, now - due), now)
                request = QueryRequest(tables=self._event_tables(ev),
                                       qos=ev.qos, budget_s=ev.budget_s)
                try:
                    ticket = self.server.submit(request)
                except ShedError:
                    self._record(ev, "shed", float("nan"))
                except Exception:
                    self._record(ev, "failed", float("nan"))
                else:
                    pending.put((ev, ticket, now))
        finally:
            for _ in workers:
                pending.put(None)
            for w in workers:
                w.join()
        return self.stats.snapshot()


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------
def slo_report(pattern: TrafficPattern, snapshot: TrafficSnapshot,
               samples: Sequence[Sample] = (), *,
               controller: Optional[dict] = None) -> dict:
    """The machine-readable SLO report a run emits: offered load, totals,
    per-class attainment/latency, burst-window goodput-p99 per class, and
    (when adaptive) the controller's decision record."""
    windows = burst_windows(pattern)
    report = {
        "pattern": {
            "duration_s": pattern.duration_s,
            "base_session_rate": pattern.base_session_rate,
            "seed": pattern.seed,
            "vocab": pattern.vocab,
            "zipf_skew": pattern.zipf_skew,
            "bursts": [[b.start_s, b.duration_s, b.multiplier]
                       for b in pattern.bursts],
        },
        "offered": snapshot.offered,
        "completed": snapshot.completed,
        "shed": snapshot.shed,
        "failed": snapshot.failed,
        "offered_rps": round(snapshot.offered_rps, 2),
        "dispatch_lag_ms": round(snapshot.dispatch_lag_ms, 3),
        "attainment": (round(snapshot.attainment, 4)
                       if snapshot.offered else None),
        "p50_ms": round(snapshot.p50_ms, 3),
        "p99_ms": round(snapshot.p99_ms, 3),
        "per_class": {},
        "burst": {},
    }
    for name, cls in snapshot.per_class.items():
        report["per_class"][name] = {
            "offered": cls.offered, "completed": cls.completed,
            "shed": cls.shed, "failed": cls.failed,
            "attainment": (round(cls.attainment, 4)
                           if cls.offered else None),
            "p50_ms": round(cls.p50_ms, 3),
            "p99_ms": round(cls.p99_ms, 3),
        }
    if windows and samples:
        for q in QoSClass:
            report["burst"][q.name] = {
                "goodput_p99_ms": round(
                    burst_p99_ms(samples, windows, qos=q), 3),
            }
    if controller is not None:
        report["controller"] = controller
    return report
