"""Realistic traffic harness + adaptive batching control plane.

``loadgen`` turns a seeded :class:`TrafficPattern` (zipfian popularity,
diurnal curves, flash crowds, mixed-QoS sessions) into a deterministic
offered-load timeline; ``driver`` replays it open-loop against a
``QueryServer`` and emits a machine-readable SLO report; ``controller``
closes the loop from live ``ServerStats``/``TierStats`` back into
``BatchPolicy`` close rules, compaction thresholds, and the hot-tier
fraction.  Guide: docs/serving.md §"Load testing and the adaptive
control plane".
"""
from repro.traffic.controller import (AdaptiveController, ControllerConfig,
                                      ControllerSnapshot, LaneKnobs)
from repro.traffic.driver import (ClassTraffic, OpenLoopDriver, Sample,
                                  TrafficSnapshot, TrafficStats,
                                  burst_p99_ms, slo_report)
from repro.traffic.loadgen import (DiurnalCurve, FlashCrowd, QoSMix,
                                   RequestEvent, RequestShape,
                                   TrafficPattern, ZipfianPopularity,
                                   burst_windows, default_shapes,
                                   generate_schedule, offered_per_window)

__all__ = [
    "AdaptiveController", "ClassTraffic", "ControllerConfig",
    "ControllerSnapshot", "DiurnalCurve", "FlashCrowd", "LaneKnobs",
    "OpenLoopDriver", "QoSMix", "RequestEvent", "RequestShape", "Sample",
    "TrafficPattern", "TrafficSnapshot", "TrafficStats",
    "ZipfianPopularity", "burst_p99_ms", "burst_windows", "default_shapes",
    "generate_schedule", "offered_per_window", "slo_report",
]
