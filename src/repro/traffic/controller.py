"""Online control plane: live telemetry → ``BatchPolicy`` / tier knobs.

The scheduler already publishes everything a controller needs
(``ServerStats``: per-class p99 and shed rates; ``TierStats``: hit rate
and garbage fraction) — this module closes the loop the way Monolith
tunes serving against real-time load instead of static configs.

Per lane, :class:`AdaptiveController` applies an AIMD-flavored rule with
a hysteresis band over the lane's latency budget.  The latency signal is
the **interval mean** — ``latency_sum_ms`` / ``completed`` deltas
between ticks — because the snapshot percentiles are cumulative
reservoirs: one warmup spike would pin a cumulative p99 above the high
water forever and wedge the controller in shrink.  Deltas of monotone
counters are the only honest per-interval read ``ServerStats`` offers.

  - **pressure** (interval shed above ``shed_pressure``, or interval
    mean latency above ``lat_high_frac`` of budget) is *directional*:
    batch-query serving sits on a throughput curve with an interior
    optimum (per-launch overhead amortizes with batch size until wide
    gathers go superlinear), so the right move depends on which side
    the server is on.  The interval mean **service time per batch**
    (``service_sum_ms``/``batches`` deltas) is the side detector: when
    batches are cheap, pressure means the close rules are starving
    amortization → **grow** ``max_batch_keys``/``max_wait_s``; when a
    batch already costs more than ``svc_high_frac`` of the budget (or
    no batch finished all interval — a stalled wide collect), growing
    made them too expensive → **shrink**;
  - **slack** (interval mean below ``lat_low_frac`` of budget and zero
    shed) → grow, but only while the key cap is actually *binding*
    (interval mean batch occupancy at least ``bind_frac`` of the cap) —
    growing a cap that idle traffic never fills just parks the knobs
    somewhere untested and poisons the next overload;
  - in between → hold.  The dead band is what prevents oscillation; the
    ``[low, high]`` gap must out-span one grow/shrink step or the
    controller would chase its own tail.

Store knobs ride the same tick: the hot-tier fraction chases a target
hit rate, and the compaction threshold relaxes under serve pressure
(compaction competes for the same cores) and tightens when calm.

Every knob write goes through the PR 4 constructor validation —
``QueryServer.retune_lane`` rebuilds the lane's ``BatchPolicy`` (its
``__post_init__`` is the oracle) and the store setters re-validate — so
a buggy rule fails loudly instead of configuring garbage.

Decisions are pure functions of (config, stats deltas): tests inject
synthetic snapshot sequences via ``stats_fn`` and step :meth:`tick` on a
simulated clock, no sleeps.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

from repro.api.types import QoSClass

__all__ = ["AdaptiveController", "ControllerConfig", "ControllerSnapshot",
           "LaneKnobs"]


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Tuning rules + hard knob bounds (all validated at construction)."""

    # hysteresis band on the interval mean latency, as fractions of the
    # lane's latency budget
    lat_low_frac: float = 0.25
    lat_high_frac: float = 0.60
    # interval shed fraction that counts as pressure regardless of
    # latency
    shed_pressure: float = 0.02
    # pressure direction: shrink only when the interval mean service
    # time per batch exceeds this fraction of the lane budget (batches
    # themselves too expensive); cheaper batches mean pressure is a
    # capacity problem and the cure is amortization, i.e. grow
    svc_high_frac: float = 0.5
    # slack growth requires the key cap to be binding: interval mean
    # batch occupancy at least this fraction of the current cap
    bind_frac: float = 0.5
    # multiplicative step sizes (AIMD-ish: gentle up, sharp down)
    grow_factor: float = 1.4
    shrink_factor: float = 0.6
    # hard bounds the knobs may never leave
    min_batch_keys: int = 256
    max_batch_keys: int = 65_536
    min_wait_s: float = 2e-4
    max_wait_s: float = 8e-3
    # ticks to hold a lane after changing it (0 = react every tick)
    cooldown_ticks: int = 0
    # a lane needs this many interval submissions before its stats count
    min_samples: int = 16
    # hot-tier rule: chase this hit rate within [min, max] fraction
    hot_target_hit_rate: float = 0.85
    hot_step: float = 0.05
    min_hot_fraction: float = 0.05
    max_hot_fraction: float = 0.60
    # compaction threshold: tight when calm, relaxed under serve pressure
    compact_calm: float = 0.25
    compact_pressure: float = 0.60

    def __post_init__(self):
        if not 0 < self.lat_low_frac < self.lat_high_frac <= 1.0:
            raise ValueError(
                f"need 0 < lat_low_frac < lat_high_frac <= 1, got "
                f"{self.lat_low_frac}, {self.lat_high_frac}")
        if not 0 < self.shed_pressure < 1:
            raise ValueError(f"shed_pressure must be in (0, 1), "
                             f"got {self.shed_pressure}")
        if not 0 < self.svc_high_frac <= 1:
            raise ValueError(f"svc_high_frac must be in (0, 1], "
                             f"got {self.svc_high_frac}")
        if not 0 < self.bind_frac <= 1:
            raise ValueError(f"bind_frac must be in (0, 1], "
                             f"got {self.bind_frac}")
        if not self.grow_factor > 1.0:
            raise ValueError(f"grow_factor must be > 1, "
                             f"got {self.grow_factor}")
        if not 0 < self.shrink_factor < 1.0:
            raise ValueError(f"shrink_factor must be in (0, 1), "
                             f"got {self.shrink_factor}")
        if not (isinstance(self.min_batch_keys, int)
                and isinstance(self.max_batch_keys, int)
                and 1 <= self.min_batch_keys <= self.max_batch_keys):
            raise ValueError(
                f"need ints 1 <= min_batch_keys <= max_batch_keys, got "
                f"{self.min_batch_keys}, {self.max_batch_keys}")
        if not 0 < self.min_wait_s <= self.max_wait_s:
            raise ValueError(f"need 0 < min_wait_s <= max_wait_s, got "
                             f"{self.min_wait_s}, {self.max_wait_s}")
        if self.cooldown_ticks < 0 or self.min_samples < 1:
            raise ValueError("cooldown_ticks must be >= 0 and "
                             "min_samples >= 1")
        if not 0 < self.hot_target_hit_rate < 1:
            raise ValueError(f"hot_target_hit_rate must be in (0, 1), "
                             f"got {self.hot_target_hit_rate}")
        if not 0 < self.hot_step < 1:
            raise ValueError(f"hot_step must be in (0, 1), "
                             f"got {self.hot_step}")
        if not (0 < self.min_hot_fraction <= self.max_hot_fraction <= 1):
            raise ValueError(
                f"need 0 < min_hot_fraction <= max_hot_fraction <= 1, got "
                f"{self.min_hot_fraction}, {self.max_hot_fraction}")
        for name in ("compact_calm", "compact_pressure"):
            v = getattr(self, name)
            if not 0 < v <= 1:
                raise ValueError(f"{name} must be in (0, 1], got {v}")


@dataclasses.dataclass
class LaneKnobs:
    """One lane's live close rules (for the obs bridge; label: qos)."""

    max_batch_keys: int = 0
    max_batch_requests: int = 0
    max_wait_ms: float = 0.0


@dataclasses.dataclass
class ControllerSnapshot:
    """The controller's own telemetry — how often it acted, and where the
    store knobs currently sit."""

    ticks: int = 0
    grows: int = 0
    shrinks: int = 0
    holds: int = 0
    hot_adjustments: int = 0
    compact_adjustments: int = 0
    hot_fraction: float = float("nan")
    compact_threshold: float = float("nan")
    per_lane: dict = dataclasses.field(default_factory=dict)


class AdaptiveController:
    """Periodically reads stats deltas and retunes the serving knobs.

    ``budgets`` maps the lanes under control to their latency budgets
    (seconds); lanes without a budget (PREFETCH) are left alone — their
    close rules are whatever slack the static policy gives them.
    ``stores`` are ``HybridKVStore``-like objects exposing
    ``set_hot_fraction`` / ``set_compaction_threshold`` /
    ``stats_snapshot``; pass none to control batching only.  Single
    writer by design: one controller per server."""

    def __init__(self, server, budgets: dict, *,
                 config: Optional[ControllerConfig] = None,
                 stores: tuple = (),
                 stats_fn: Optional[Callable] = None):
        if not budgets:
            raise ValueError("budgets must map at least one QoS class to "
                             "a latency budget in seconds")
        self.server = server
        self.config = config or ControllerConfig()
        self.budgets = {QoSClass.parse(q): float(b)
                        for q, b in budgets.items()}
        for q, b in self.budgets.items():
            if not b > 0:
                raise ValueError(f"budget for {q.name} must be > 0, got {b}")
        self.stores = tuple(stores)
        self._stats_fn = stats_fn or server.stats_snapshot
        self._last = self._stats_fn()
        self._last_tiers = self._tier_totals()
        self._cooldown = {q: 0 for q in self.budgets}
        self._lock = threading.Lock()
        self._snap = ControllerSnapshot()
        self.history: list[dict] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # the request cap is a close rule too: a grown key budget is
        # useless if batches still close at the old request count.  Keep
        # each lane's requests-per-key shape from its starting policy
        # and scale both caps together.
        self._req_ratio = {}
        for name, pol in self.server.lane_policies().items():
            q = QoSClass.parse(name)
            self._req_ratio[q] = (pol.max_batch_requests
                                  / max(pol.max_batch_keys, 1))
        # clamp whatever the server starts with into our bounds so the
        # monotone-approach invariant holds from tick zero
        for q in self.budgets:
            cur = self.server.lane_policies()[q.name]
            self._apply(q, cur.max_batch_keys, cur.max_wait_s)

    # ------------------------------------------------------------------
    def _tier_totals(self) -> dict:
        tot = {"hot_hits": 0, "cold_misses": 0}
        for store in self.stores:
            st = store.stats_snapshot()
            tot["hot_hits"] += st.hot_hits
            tot["cold_misses"] += st.cold_misses
        return tot

    def _clamp(self, keys: float, wait: float) -> tuple[int, float]:
        cfg = self.config
        keys_i = int(min(max(int(round(keys)), cfg.min_batch_keys),
                         cfg.max_batch_keys))
        wait_f = float(min(max(wait, cfg.min_wait_s), cfg.max_wait_s))
        return keys_i, wait_f

    def _apply(self, q: QoSClass, keys: float, wait: float) -> dict:
        keys_i, wait_f = self._clamp(keys, wait)
        # the request cap scales with the key cap at the lane's initial
        # requests-per-key ratio: both are close rules, and a batch that
        # hits the stale request count never reaches the grown key budget
        reqs_i = max(int(round(keys_i * self._req_ratio.get(q, 1.0))), 1)
        # BatchPolicy.__post_init__ (PR 4) is the validation oracle: the
        # rebuilt policy raises before anything reaches the scheduler
        pol = self.server.retune_lane(q, max_batch_keys=keys_i,
                                      max_batch_requests=reqs_i,
                                      max_wait_s=wait_f)
        return {"max_batch_keys": pol.max_batch_keys,
                "max_batch_requests": pol.max_batch_requests,
                "max_wait_s": pol.max_wait_s}

    def _lane_decision(self, q: QoSClass, cur, prev,
                       svc_ms: Optional[float],
                       batch_keys: Optional[float],
                       cap_keys: int) -> tuple[str, str]:
        """(action, reason) for one lane from the interval stats deltas.

        ``svc_ms``/``batch_keys`` are the server-wide interval mean
        service time and key occupancy per micro-batch (None when no
        batch finished in the interval); ``cap_keys`` is the lane's
        live ``max_batch_keys``."""
        cfg = self.config
        budget = self.budgets[q]
        d_submitted = cur.submitted - prev.submitted
        d_shed = cur.shed - prev.shed
        if self._cooldown[q] > 0:
            self._cooldown[q] -= 1
            return "hold", "cooldown"
        if d_submitted < cfg.min_samples:
            return "hold", "too few interval samples"
        shed_frac = d_shed / d_submitted
        d_completed = cur.completed - prev.completed
        mean_ms = ((cur.latency_sum_ms - prev.latency_sum_ms) / d_completed
                   if d_completed > 0 else None)
        pressure = shed_frac > cfg.shed_pressure or (
            mean_ms is not None and mean_ms * 1e-3
            > cfg.lat_high_frac * budget)
        if pressure:
            # which side of the throughput optimum are we on?  no
            # finished batch all interval counts as expensive: a wide
            # collect is stalling the pipeline
            if svc_ms is None or svc_ms * 1e-3 > cfg.svc_high_frac * budget:
                svc = "none" if svc_ms is None else f"{svc_ms:.1f}ms"
                return "shrink", (f"pressure (shed {shed_frac:.1%}) with "
                                  f"expensive batches (svc {svc})")
            return "grow", (f"pressure (shed {shed_frac:.1%}, mean "
                            f"{mean_ms or float('nan'):.1f}ms) with cheap "
                            f"batches (svc {svc_ms:.1f}ms)")
        if mean_ms is None:
            # submissions but no completions and no sheds: everything is
            # queued — no latency read yet, don't thrash
            return "hold", "no interval completions"
        if mean_ms * 1e-3 < cfg.lat_low_frac * budget and shed_frac == 0.0:
            if batch_keys is not None and batch_keys \
                    >= cfg.bind_frac * cap_keys:
                return "grow", f"mean {mean_ms:.1f}ms under low water"
            return "hold", "slack but key cap not binding"
        return "hold", "in band"

    # ------------------------------------------------------------------
    def tick(self) -> dict:
        """One control step: read stats, decide per lane, actuate."""
        cfg = self.config
        snap = self._stats_fn()
        record: dict = {"lanes": {}, "stores": {}}
        any_pressure = False
        d_batches = snap.batches - self._last.batches
        svc_ms = ((snap.service_sum_ms - self._last.service_sum_ms)
                  / d_batches if d_batches > 0 else None)
        batch_keys = ((snap.keys_requested - self._last.keys_requested)
                      / d_batches if d_batches > 0 else None)
        with self._lock:
            self._snap.ticks += 1
            for q in sorted(self.budgets):
                cur = snap.per_class.get(q.name)
                prev = self._last.per_class.get(q.name)
                if cur is None or prev is None:
                    continue
                live_cap = self.server.lane_policies()[q.name]
                action, reason = self._lane_decision(
                    q, cur, prev, svc_ms, batch_keys,
                    live_cap.max_batch_keys)
                keys, wait = live_cap.max_batch_keys, live_cap.max_wait_s
                if action == "shrink":
                    any_pressure = True
                    knobs = self._apply(q, keys * cfg.shrink_factor,
                                        wait * cfg.shrink_factor)
                    self._snap.shrinks += 1
                    if (knobs["max_batch_keys"], knobs["max_wait_s"]) \
                            != (keys, wait):
                        self._cooldown[q] = cfg.cooldown_ticks
                elif action == "grow":
                    knobs = self._apply(q, keys * cfg.grow_factor,
                                        wait * cfg.grow_factor)
                    self._snap.grows += 1
                    if (knobs["max_batch_keys"], knobs["max_wait_s"]) \
                            != (keys, wait):
                        self._cooldown[q] = cfg.cooldown_ticks
                else:
                    knobs = {"max_batch_keys": keys,
                             "max_batch_requests":
                                 live_cap.max_batch_requests,
                             "max_wait_s": wait}
                    self._snap.holds += 1
                record["lanes"][q.name] = {"action": action,
                                           "reason": reason, **knobs}
            self._follow_uncontrolled(record)
            record["stores"] = self._store_tick(any_pressure)
            self._last = snap
            self.history.append(record)
        return record

    def _follow_uncontrolled(self, record: dict) -> None:  # lock-held: _lock
        """Budget-less lanes (PREFETCH) track the *widest* controlled
        lane.  They have no deadline to protect — but their batches
        share the serve pipeline, so leaving them on a stale tiny close
        rule floods it with unamortized launches and starves the lanes
        that do have budgets."""
        live = self.server.lane_policies()
        widest_keys = widest_wait = None
        for q in self.budgets:
            pol = live.get(q.name)
            if pol is None:
                continue
            widest_keys = pol.max_batch_keys if widest_keys is None \
                else max(widest_keys, pol.max_batch_keys)
            widest_wait = pol.max_wait_s if widest_wait is None \
                else max(widest_wait, pol.max_wait_s)
        if widest_keys is None:
            return
        for q in QoSClass:
            if q in self.budgets or q.name not in live:
                continue
            pol = live[q.name]
            if (pol.max_batch_keys, pol.max_wait_s) \
                    == (widest_keys, widest_wait):
                continue
            knobs = self._apply(q, widest_keys, widest_wait)
            record["lanes"][q.name] = {"action": "follow",
                                       "reason": "widest controlled lane",
                                       **knobs}

    def _store_tick(self, pressure: bool) -> dict:
        """Hot-tier fraction chases the target hit rate; compaction
        threshold follows the serve-pressure regime."""
        cfg = self.config
        out: dict = {}
        if not self.stores:
            return out
        tiers = self._tier_totals()
        d_hits = tiers["hot_hits"] - self._last_tiers["hot_hits"]
        d_miss = tiers["cold_misses"] - self._last_tiers["cold_misses"]
        self._last_tiers = tiers
        threshold = cfg.compact_pressure if pressure else cfg.compact_calm
        hit_rate = d_hits / (d_hits + d_miss) \
            if (d_hits + d_miss) >= cfg.min_samples else None
        fractions = []
        for store in self.stores:
            if store.compaction_threshold != threshold:
                store.set_compaction_threshold(threshold)
                self._snap.compact_adjustments += 1
            frac = store.hot_fraction
            if hit_rate is not None:
                if hit_rate < cfg.hot_target_hit_rate:
                    target = min(frac + cfg.hot_step, cfg.max_hot_fraction)
                elif hit_rate > 0.98:
                    target = max(frac - cfg.hot_step, cfg.min_hot_fraction)
                else:
                    target = frac
                if abs(target - frac) > 1e-9:
                    store.set_hot_fraction(target)
                    self._snap.hot_adjustments += 1
                    frac = store.hot_fraction
            fractions.append(frac)
        self._snap.hot_fraction = (sum(fractions) / len(fractions)
                                   if fractions else float("nan"))
        self._snap.compact_threshold = threshold
        out["hit_rate"] = hit_rate
        out["compact_threshold"] = threshold
        out["hot_fraction"] = self._snap.hot_fraction
        return out

    # ------------------------------------------------------------------
    def snapshot(self) -> ControllerSnapshot:
        with self._lock:
            snap = dataclasses.replace(
                self._snap, per_lane={})
            for q in self.budgets:
                pol = self.server.lane_policies()[q.name]
                snap.per_lane[q.name] = LaneKnobs(
                    max_batch_keys=pol.max_batch_keys,
                    max_batch_requests=pol.max_batch_requests,
                    max_wait_ms=pol.max_wait_s * 1e3)
            return snap

    def decisions(self) -> dict:
        """Compact summary for the SLO report."""
        snap = self.snapshot()
        return {
            "ticks": snap.ticks, "grows": snap.grows,
            "shrinks": snap.shrinks, "holds": snap.holds,
            "hot_adjustments": snap.hot_adjustments,
            "compact_adjustments": snap.compact_adjustments,
            "lanes": {name: {"max_batch_keys": k.max_batch_keys,
                             "max_batch_requests": k.max_batch_requests,
                             "max_wait_ms": round(k.max_wait_ms, 3)}
                      for name, k in snap.per_lane.items()},
        }

    # -- background loop ------------------------------------------------
    def start(self, period_s: float = 0.25) -> None:
        """Idempotent background tick loop (real clock)."""
        if not period_s > 0:
            raise ValueError(f"period_s must be > 0, got {period_s}")
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()

        def loop():
            while not self._stop.wait(period_s):
                self.tick()

        self._thread = threading.Thread(target=loop,
                                        name="adaptive-controller",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
