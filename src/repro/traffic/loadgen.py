"""Deterministic, seedable traffic generation — what production offers.

Every bench before this PR drove the server with closed loops of fixed
shape (N threads, fire-wait-fire).  Production traffic is nothing like
that: key popularity is zipfian, offered load swings through diurnal
cycles and flash crowds, and requests arrive in *sessions* — a user's
retrieval is followed by ranking calls and speculative prefetches with
think-time gaps — open-loop, indifferent to whether the server keeps up.

This module turns a :class:`TrafficPattern` into that offered stream,
**offline and reproducibly**: ``generate_schedule(pattern)`` computes the
full event timeline (absolute offer times, per-request QoS class, key
ranks, latency budget) from a single seeded ``np.random.Generator`` with
no wall-clock reads, so the same seed yields the byte-identical timeline
— the property the distribution tests pin — and two runs against
different server configs are offered *exactly* the same load.

Pieces:

  - :class:`ZipfianPopularity` — rank-frequency law with configurable
    skew and an **analytic pmf** (bounded support, unlike
    ``np.random.zipf``), so empirical frequencies are testable against
    closed form;
  - :class:`DiurnalCurve` — raised-cosine rate multiplier between trough
    (1.0) and peak;
  - :class:`FlashCrowd` — a burst window multiplying the offered rate
    (the paper's update-storm / hot-event regime);
  - :class:`QoSMix` + :class:`RequestShape` — per-class request mix,
    key-set sizes, and latency budgets;
  - :class:`TrafficPattern.rate` — the composed sessions/s curve;
    session arrivals are a non-homogeneous Poisson process (thinning),
    requests within a session follow exponential think times.

``repro.traffic.driver`` replays a schedule open-loop against a
``QueryServer``; ``repro.traffic.controller`` closes the loop back into
``BatchPolicy``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.api.types import QoSClass

__all__ = [
    "DiurnalCurve", "FlashCrowd", "QoSMix", "RequestEvent", "RequestShape",
    "TrafficPattern", "ZipfianPopularity", "burst_windows",
    "generate_schedule", "offered_per_window",
]


# ---------------------------------------------------------------------------
# key popularity
# ---------------------------------------------------------------------------
class ZipfianPopularity:
    """Zipf rank-frequency law over a *bounded* vocabulary.

    ``p(rank r) ∝ (r + 1) ** -skew`` for ranks ``0..vocab-1`` — the
    classic content-popularity model (skew ~0.9–1.2 for item catalogs).
    Unlike ``np.random.zipf`` the support is bounded and the pmf is
    available in closed form, so tests can check empirical frequencies
    against ``pmf()`` exactly instead of against a truncated
    approximation.  ``skew=0`` degenerates to uniform."""

    def __init__(self, vocab: int, skew: float = 1.1):
        if not isinstance(vocab, int) or vocab < 1:
            raise ValueError(f"vocab must be an int >= 1, got {vocab!r}")
        if skew < 0:
            raise ValueError(f"skew must be >= 0, got {skew}")
        self.vocab = vocab
        self.skew = float(skew)
        weights = np.arange(1, vocab + 1, dtype=np.float64) ** -self.skew
        self._pmf = weights / weights.sum()
        self._cdf = np.cumsum(self._pmf)
        self._cdf[-1] = 1.0          # guard the fp tail: u=0.999.. must land

    def pmf(self) -> np.ndarray:
        """Analytic probability of each rank (rank 0 = hottest)."""
        return self._pmf.copy()

    def sample(self, rng: np.random.Generator, size) -> np.ndarray:
        """Ranks drawn by inverse-CDF — one uniform per draw, so the
        consumed rng stream length is shape-deterministic."""
        return np.searchsorted(self._cdf, rng.random(size),
                               side="right").astype(np.int64)


# ---------------------------------------------------------------------------
# load curves
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DiurnalCurve:
    """Raised-cosine daily cycle: multiplier 1.0 at the trough,
    ``peak_to_trough`` at the peak, period ``period_s``.  ``phase_frac``
    slides where t=0 sits in the cycle (0.0 = trough, 0.5 = peak)."""

    period_s: float = 86_400.0
    peak_to_trough: float = 4.0
    phase_frac: float = 0.0

    def __post_init__(self):
        if not self.period_s > 0:
            raise ValueError(f"period_s must be > 0, got {self.period_s}")
        if not self.peak_to_trough >= 1.0:
            raise ValueError(f"peak_to_trough must be >= 1, "
                             f"got {self.peak_to_trough}")

    def multiplier(self, t_s):
        t = np.asarray(t_s, dtype=np.float64)
        x = 0.5 - 0.5 * np.cos(2 * np.pi * (t / self.period_s
                                            + self.phase_frac))
        return 1.0 + (self.peak_to_trough - 1.0) * x


@dataclasses.dataclass(frozen=True)
class FlashCrowd:
    """One burst window: offered rate multiplied by ``multiplier`` for
    ``[start_s, start_s + duration_s)`` — a hot event / push notification
    / retry storm."""

    start_s: float
    duration_s: float
    multiplier: float = 4.0

    def __post_init__(self):
        if self.start_s < 0 or not self.duration_s > 0:
            raise ValueError(f"burst window invalid: start={self.start_s} "
                             f"duration={self.duration_s}")
        if not self.multiplier >= 1.0:
            raise ValueError(f"burst multiplier must be >= 1, "
                             f"got {self.multiplier}")

    def active(self, t_s) -> np.ndarray:
        t = np.asarray(t_s, dtype=np.float64)
        return (t >= self.start_s) & (t < self.start_s + self.duration_s)


# ---------------------------------------------------------------------------
# request mix + shapes
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QoSMix:
    """Relative request weights per QoS class within a session trace —
    PREFETCH-heavy by default (speculative warming outweighs user-facing
    calls in offered volume, the realistic shape)."""

    ranking: float = 1.0
    retrieval: float = 1.0
    prefetch: float = 2.0

    def __post_init__(self):
        for f in dataclasses.fields(self):
            if getattr(self, f.name) < 0:
                raise ValueError(f"{f.name} weight must be >= 0")
        if not (self.ranking + self.retrieval + self.prefetch) > 0:
            raise ValueError("QoSMix needs at least one positive weight")

    def fractions(self) -> dict[QoSClass, float]:
        total = self.ranking + self.retrieval + self.prefetch
        return {QoSClass.RANKING: self.ranking / total,
                QoSClass.RETRIEVAL: self.retrieval / total,
                QoSClass.PREFETCH: self.prefetch / total}


@dataclasses.dataclass(frozen=True)
class RequestShape:
    """Per-class request template: ``{table: n_keys}`` drawn zipfian per
    request, and the latency budget (None = deadline-less)."""

    tables: tuple[tuple[str, int], ...]
    budget_s: Optional[float] = None

    def __post_init__(self):
        if not self.tables:
            raise ValueError("RequestShape needs at least one table")
        for name, n in self.tables:
            if not isinstance(name, str) or not name:
                raise ValueError(f"bad table name {name!r}")
            if not isinstance(n, int) or n < 1:
                raise ValueError(f"n_keys for {name!r} must be int >= 1")
        if self.budget_s is not None and not self.budget_s > 0:
            raise ValueError(f"budget_s must be > 0, got {self.budget_s}")


def default_shapes(table: str = "item_attr") -> dict[QoSClass, RequestShape]:
    """Single-table defaults mirroring the serving benches: RANKING is
    small + tight-budget, RETRIEVAL wider, PREFETCH widest + budget-less."""
    return {
        QoSClass.RANKING: RequestShape(((table, 96),), budget_s=0.050),
        QoSClass.RETRIEVAL: RequestShape(((table, 128),), budget_s=0.100),
        QoSClass.PREFETCH: RequestShape(((table, 192),), budget_s=None),
    }


# ---------------------------------------------------------------------------
# the pattern + schedule
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RequestEvent:
    """One offered request: ``t_s`` is the absolute offer time from run
    start (open-loop — the driver fires at this time whether or not the
    server kept up); ``ranks`` are zipfian key ranks per table, mapped to
    actual key ids by the driver."""

    t_s: float
    session: int
    qos: QoSClass
    ranks: dict[str, np.ndarray]
    budget_s: Optional[float]

    @property
    def n_keys(self) -> int:
        return sum(len(r) for r in self.ranks.values())


@dataclasses.dataclass(frozen=True)
class TrafficPattern:
    """Everything that determines the offered stream.  Frozen + seeded:
    the schedule is a pure function of this object."""

    duration_s: float = 10.0
    base_session_rate: float = 20.0      # sessions/s at the diurnal trough
    seed: int = 0
    vocab: int = 100_000
    zipf_skew: float = 1.1
    diurnal: Optional[DiurnalCurve] = None
    bursts: tuple[FlashCrowd, ...] = ()
    mix: QoSMix = dataclasses.field(default_factory=QoSMix)
    requests_per_session: tuple[int, int] = (2, 6)
    think_time_s: float = 0.040          # mean exponential think gap
    shapes: Optional[dict] = None        # {QoSClass: RequestShape}

    def __post_init__(self):
        if not self.duration_s > 0:
            raise ValueError(f"duration_s must be > 0, got {self.duration_s}")
        if not self.base_session_rate > 0:
            raise ValueError(f"base_session_rate must be > 0, "
                             f"got {self.base_session_rate}")
        lo, hi = self.requests_per_session
        if not (isinstance(lo, int) and isinstance(hi, int)
                and 1 <= lo <= hi):
            raise ValueError(f"requests_per_session must be ints "
                             f"1 <= lo <= hi, got {lo, hi}")
        if self.think_time_s < 0:
            raise ValueError(f"think_time_s must be >= 0, "
                             f"got {self.think_time_s}")

    # ------------------------------------------------------------------
    def resolved_shapes(self) -> dict[QoSClass, RequestShape]:
        return dict(self.shapes) if self.shapes else default_shapes()

    def rate(self, t_s):
        """Offered session rate at ``t_s`` (sessions/s): base × diurnal ×
        every active burst's multiplier."""
        t = np.asarray(t_s, dtype=np.float64)
        out = np.full(t.shape, self.base_session_rate, dtype=np.float64)
        if self.diurnal is not None:
            out = out * self.diurnal.multiplier(t)
        for burst in self.bursts:
            out = np.where(burst.active(t), out * burst.multiplier, out)
        return out if out.shape else float(out)

    def peak_rate(self) -> float:
        """Upper bound on ``rate`` over the run (thinning envelope)."""
        peak = self.base_session_rate
        if self.diurnal is not None:
            peak *= self.diurnal.peak_to_trough
        for burst in self.bursts:
            peak *= burst.multiplier        # overlapping bursts compound
        return peak


def burst_windows(pattern: TrafficPattern) -> list[tuple[float, float]]:
    """The ``[start, end)`` burst windows, clipped to the run."""
    return [(b.start_s, min(b.start_s + b.duration_s, pattern.duration_s))
            for b in pattern.bursts if b.start_s < pattern.duration_s]


def _session_arrivals(pattern: TrafficPattern,
                      rng: np.random.Generator) -> np.ndarray:
    """Non-homogeneous Poisson session starts over ``[0, duration_s)`` by
    thinning against the peak-rate envelope."""
    lam_max = pattern.peak_rate()
    out = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= pattern.duration_s:
            break
        if rng.random() * lam_max < pattern.rate(t):
            out.append(t)
    return np.asarray(out, dtype=np.float64)


def generate_schedule(pattern: TrafficPattern) -> list[RequestEvent]:
    """The full offered timeline, sorted by offer time.

    Pure function of ``pattern`` (single seeded generator, no wall clock):
    identical patterns yield byte-identical schedules.  Sessions spill
    their think-time tails past ``duration_s`` naturally — a user mid-
    session at the end of the window finishes it."""
    rng = np.random.default_rng(pattern.seed)
    zipf = ZipfianPopularity(pattern.vocab, pattern.zipf_skew)
    shapes = pattern.resolved_shapes()
    fracs = pattern.mix.fractions()
    classes = [q for q in QoSClass if fracs[q] > 0 and q in shapes]
    if not classes:
        raise ValueError("QoSMix × shapes leaves no usable QoS class")
    weights = np.asarray([fracs[q] for q in classes], dtype=np.float64)
    weights /= weights.sum()
    cdf = np.cumsum(weights)
    cdf[-1] = 1.0

    lo, hi = pattern.requests_per_session
    events: list[RequestEvent] = []
    for sid, t0 in enumerate(_session_arrivals(pattern, rng)):
        n_req = int(rng.integers(lo, hi + 1))
        t = float(t0)
        for i in range(n_req):
            qos = classes[int(np.searchsorted(cdf, rng.random(),
                                              side="right"))]
            shape = shapes[qos]
            ranks = {name: zipf.sample(rng, n)
                     for name, n in shape.tables}
            events.append(RequestEvent(t_s=t, session=sid, qos=qos,
                                       ranks=ranks,
                                       budget_s=shape.budget_s))
            if i + 1 < n_req:
                t += float(rng.exponential(pattern.think_time_s)) \
                    if pattern.think_time_s else 0.0
    events.sort(key=lambda ev: (ev.t_s, ev.session))
    return events


def offered_per_window(events: Sequence[RequestEvent],
                       window_s: float) -> np.ndarray:
    """Offered requests/s per ``window_s`` bucket — the offered-load curve
    a report or test compares against the pattern's analytic rate."""
    if not window_s > 0:
        raise ValueError(f"window_s must be > 0, got {window_s}")
    if not events:
        return np.zeros(0, dtype=np.float64)
    ts = np.asarray([ev.t_s for ev in events], dtype=np.float64)
    n_bins = int(np.floor(ts.max() / window_s)) + 1
    counts = np.bincount((ts / window_s).astype(np.int64),
                         minlength=n_bins)
    return counts / window_s
