"""Process-wide metrics registry: thread-safe Counter / Gauge / Histogram.

Deliberately tiny and stdlib-only (no prometheus_client, no numpy, no
jax) so the fabric's shard children can use it without dragging the
device runtime into their import graph.  The exposition side lives in
:mod:`repro.obs.exporter`.

Concurrency contract (verified by ``tools/analyze``): every metric owns a
lock guarding its label→value map, and the registry owns a lock guarding
the name→metric map plus the collector list.  Collectors are snapshotted
under the lock but *invoked outside it*, so a collector may itself create
metrics or set values without deadlocking.
"""
from __future__ import annotations

import re
import threading
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

_METRIC_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# Seconds-scale latency buckets: 0.1 ms .. 10 s, roughly log-spaced.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 10.0, float("inf"))

# One exposition sample: (suffix appended to the metric name, extra
# labels merged over the series labels, value).
Sample = Tuple[str, Tuple[Tuple[str, str], ...], float]


def _check_name(name: str) -> str:
    if not _METRIC_NAME_RE.match(name):
        raise ValueError(f"invalid metric name {name!r}")
    return name


def _check_labelnames(labelnames: Sequence[str]) -> Tuple[str, ...]:
    out = tuple(labelnames)
    for ln in out:
        if not _LABEL_NAME_RE.match(ln) or ln.startswith("__"):
            raise ValueError(f"invalid label name {ln!r}")
    if len(set(out)) != len(out):
        raise ValueError(f"duplicate label names in {out!r}")
    return out


class Metric:
    """Base: one named family of labelled series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        self.name = _check_name(name)
        self.help = help
        self.labelnames = _check_labelnames(labelnames)
        self._lock = threading.Lock()
        self._series: Dict[Tuple[str, ...], object] = {}  # guarded-by: _lock (strict)

    def _key(self, labels: Dict[str, object]) -> Tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, "
                f"declared {sorted(self.labelnames)}")
        return tuple(str(labels[ln]) for ln in self.labelnames)

    def _labelpairs(self, key: Tuple[str, ...]) -> Tuple[Tuple[str, str], ...]:
        return tuple(zip(self.labelnames, key))

    def samples(self) -> List[Sample]:
        raise NotImplementedError


class Counter(Metric):
    """Monotonically increasing count.

    ``inc`` is the write face for code that owns the count; ``set_total``
    is the bridge face for scrape-time collectors that adopt a monotonic
    total maintained elsewhere (e.g. a stats-silo snapshot).
    """

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels: object) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counter decrease ({amount})")
        key = self._key(labels)
        with self._lock:
            self._series[key] = float(self._series.get(key, 0.0)) + amount

    def set_total(self, value: float, **labels: object) -> None:
        key = self._key(labels)
        with self._lock:
            self._series[key] = float(value)

    def value(self, **labels: object) -> float:
        key = self._key(labels)
        with self._lock:
            return float(self._series.get(key, 0.0))

    def samples(self) -> List[Sample]:
        with self._lock:
            items = sorted(self._series.items())
        return [("", self._labelpairs(k), float(v)) for k, v in items]


class Gauge(Metric):
    """A value that can go up and down."""

    kind = "gauge"

    def set(self, value: float, **labels: object) -> None:
        key = self._key(labels)
        with self._lock:
            self._series[key] = float(value)

    def inc(self, amount: float = 1.0, **labels: object) -> None:
        key = self._key(labels)
        with self._lock:
            self._series[key] = float(self._series.get(key, 0.0)) + amount

    def dec(self, amount: float = 1.0, **labels: object) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels: object) -> float:
        key = self._key(labels)
        with self._lock:
            return float(self._series.get(key, 0.0))

    def samples(self) -> List[Sample]:
        with self._lock:
            items = sorted(self._series.items())
        return [("", self._labelpairs(k), float(v)) for k, v in items]


class Histogram(Metric):
    """Cumulative-bucket histogram (Prometheus semantics).

    Per-series state is ``[count_b0, count_b1, ..., sum]`` with
    *non*-cumulative per-bucket counts; ``samples()`` renders the
    cumulative ``le`` view plus ``_sum`` and ``_count``.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        bs = sorted(float(b) for b in buckets)
        if not bs:
            raise ValueError(f"{name}: empty bucket list")
        if bs[-1] != float("inf"):
            bs.append(float("inf"))
        if len(set(bs)) != len(bs):
            raise ValueError(f"{name}: duplicate buckets")
        self.buckets: Tuple[float, ...] = tuple(bs)

    def observe(self, value: float, **labels: object) -> None:
        v = float(value)
        key = self._key(labels)
        i = 0
        for i, ub in enumerate(self.buckets):  # noqa: B007 — small tuple
            if v <= ub:
                break
        with self._lock:
            buf = self._series.get(key)
            if buf is None:
                buf = [0] * len(self.buckets) + [0.0]
                self._series[key] = buf
            buf[i] += 1
            buf[-1] += v

    def samples(self) -> List[Sample]:
        with self._lock:
            items = sorted((k, list(v)) for k, v in self._series.items())
        out: List[Sample] = []
        for key, buf in items:
            base = self._labelpairs(key)
            running = 0
            for ub, n in zip(self.buckets, buf[:-1]):
                running += n
                le = "+Inf" if ub == float("inf") else format(ub, "g")
                out.append(("_bucket", base + (("le", le),), float(running)))
            out.append(("_count", base, float(running)))
            out.append(("_sum", base, float(buf[-1])))
        return out


class Registry:
    """Get-or-create home for metrics plus scrape-time collectors.

    A *collector* is a zero-arg callable run at the top of every
    ``collect()``; bridges use it to pull a fresh snapshot out of an
    existing stats silo and push it into registry metrics, so the silo
    stays the single source of truth and pays nothing between scrapes.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Metric] = {}  # guarded-by: _lock (strict)
        self._collectors: List[Callable[[], None]] = []  # guarded-by: _lock (strict)

    def _get_or_create(self, cls: type, name: str, help: str,
                       labelnames: Sequence[str],
                       **kwargs: object) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, labelnames, **kwargs)
                self._metrics[name] = m
                return m
        if not isinstance(m, cls):
            raise ValueError(
                f"{name}: registered as {m.kind}, requested {cls.kind}")
        if tuple(labelnames) != m.labelnames:
            raise ValueError(
                f"{name}: registered with labels {m.labelnames}, "
                f"requested {tuple(labelnames)}")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(
            Histogram, name, help, labelnames, buckets=buckets)

    def register_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            self._collectors.append(fn)

    def unregister_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            if fn in self._collectors:
                self._collectors.remove(fn)

    def get(self, name: str) -> Optional[Metric]:
        with self._lock:
            return self._metrics.get(name)

    def collect(self) -> List[Metric]:
        """Run collectors, then return metrics sorted by name."""
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            fn()   # outside the lock: collectors may create/set metrics
        with self._lock:
            return [self._metrics[n] for n in sorted(self._metrics)]


# Process-default registry.  Library code takes a Registry parameter and
# defaults to this, so tests can use private registries.
REGISTRY = Registry()


def get_registry() -> Registry:
    return REGISTRY
