"""Sampled per-request spans across the serving pipeline.

Span taxonomy for one request through a ``QueryServer`` (names are part
of the contract — ``docs/observability.md`` documents them, and
``tests/test_observability.py`` asserts the full chain):

    admission -> lane_wait -> coalesce -> version_pin -> begin
              -> device -> finish -> scatter

under a per-request ``serve`` root span.  The Router adds ``route`` and
per-shard ``shard_rpc`` spans and merges the shard-side span lists
carried back in the wire response into one cross-process timeline.

Timestamps are ``time.monotonic()``: CLOCK_MONOTONIC on Linux is a
system-wide clock, so spans stamped in the router and in shard child
processes on the same host share a comparable timebase.

Sampling: a tracer decides at the *edge* (``sample()``) whether a fresh
request gets a trace context.  Downstream tracers (shard children run
``sample_rate=0``) still record spans for requests that arrive with a
context — the decision is made once, at the outermost entry point.
"""
from __future__ import annotations

import collections
import os
import random
import threading
import time
from typing import Deque, Dict, List, Optional, Tuple


def new_id() -> str:
    """A 64-bit random hex id (trace or span)."""
    return os.urandom(8).hex()


def now() -> float:
    return time.monotonic()


class Span:
    """One timed section of one request.  Plain record, wire-friendly."""

    __slots__ = ("trace_id", "span_id", "parent_id", "name", "proc",
                 "t0", "t1", "tags")

    def __init__(self, trace_id: str, name: str, t0: float, t1: float,
                 parent_id: Optional[str] = None, proc: str = "",
                 span_id: Optional[str] = None,
                 tags: Optional[Dict[str, object]] = None):
        self.trace_id = trace_id
        self.span_id = span_id if span_id is not None else new_id()
        self.parent_id = parent_id
        self.name = name
        self.proc = proc
        self.t0 = float(t0)
        self.t1 = float(t1)
        self.tags = dict(tags) if tags else {}

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def to_wire(self) -> Dict[str, object]:
        return {"trace_id": self.trace_id, "span_id": self.span_id,
                "parent_id": self.parent_id, "name": self.name,
                "proc": self.proc, "t0": self.t0, "t1": self.t1,
                "tags": self.tags}

    @classmethod
    def from_wire(cls, d: Dict[str, object]) -> "Span":
        return cls(trace_id=str(d["trace_id"]), name=str(d["name"]),
                   t0=float(d["t0"]), t1=float(d["t1"]),
                   parent_id=d.get("parent_id"),  # type: ignore[arg-type]
                   proc=str(d.get("proc", "")),
                   span_id=str(d["span_id"]),
                   tags=d.get("tags") or {})  # type: ignore[arg-type]

    def __repr__(self) -> str:
        return (f"Span({self.name!r} proc={self.proc!r} "
                f"[{self.t0:.6f},{self.t1:.6f}] trace={self.trace_id})")


class Tracer:
    """Collects finished spans per trace id, bounded by ``capacity``
    traces (oldest evicted first)."""

    def __init__(self, sample_rate: float = 0.0, capacity: int = 256,
                 proc: str = "main"):
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError(f"sample_rate {sample_rate} not in [0, 1]")
        self.sample_rate = float(sample_rate)
        self.capacity = int(capacity)
        self.proc = proc
        self._rng = random.Random(os.urandom(8))
        self._lock = threading.Lock()
        self._spans: Dict[str, List[Span]] = {}  # guarded-by: _lock (strict)
        self._order: Deque[str] = collections.deque()  # guarded-by: _lock (strict)
        self._sampled_total = 0  # guarded-by: _lock (strict)

    def sample(self) -> Optional[str]:
        """Edge decision: a fresh trace id if this request is sampled,
        else None.  ``sample_rate == 0`` short-circuits — this is the
        only tracing cost on an untraced hot path."""
        rate = self.sample_rate
        if rate <= 0.0:
            return None
        if rate < 1.0 and self._rng.random() >= rate:
            return None
        tid = new_id()
        with self._lock:
            self._sampled_total += 1
        return tid

    @property
    def sampled_total(self) -> int:
        with self._lock:
            return self._sampled_total

    def span(self, trace_id: str, name: str, t0: float, t1: float,
             parent_id: Optional[str] = None,
             span_id: Optional[str] = None,
             tags: Optional[Dict[str, object]] = None) -> Span:
        """Create a finished span and record it."""
        s = Span(trace_id, name, t0, t1, parent_id=parent_id,
                 proc=self.proc, span_id=span_id, tags=tags)
        self.record([s])
        return s

    def record(self, spans: List[Span]) -> None:
        with self._lock:
            for s in spans:
                bucket = self._spans.get(s.trace_id)
                if bucket is None:
                    bucket = []
                    self._spans[s.trace_id] = bucket
                    self._order.append(s.trace_id)
                bucket.append(s)
            while len(self._order) > self.capacity:
                evicted = self._order.popleft()
                self._spans.pop(evicted, None)

    def take(self, trace_id: str) -> List[Span]:
        """Remove and return all spans recorded for *trace_id*."""
        with self._lock:
            spans = self._spans.pop(trace_id, [])
            if spans:
                try:
                    self._order.remove(trace_id)
                except ValueError:
                    pass
        return spans

    def peek(self, trace_id: str) -> List[Span]:
        with self._lock:
            return list(self._spans.get(trace_id, ()))

    def trace_ids(self) -> List[str]:
        with self._lock:
            return list(self._order)


def sort_timeline(spans: List[Span]) -> List[Span]:
    """Spans ordered by start time — the merged cross-process view."""
    return sorted(spans, key=lambda s: (s.t0, s.t1))
