"""Prometheus text-format exposition + a stdlib ``/metrics`` endpoint.

``render_text`` produces the text exposition format (version 0.0.4);
``parse_text`` is the inverse used by tests and by CI's mid-run scrape
assertions; ``MetricsServer`` serves it over ``http.server``.  All
stdlib — shard children (jax-free, enforced by the import-graph checker)
can serve their own endpoint.
"""
from __future__ import annotations

import json
import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional, Tuple

from repro.obs.metrics import Registry, REGISTRY

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# A flattened series key: (metric name incl. suffix, sorted label pairs).
SeriesKey = Tuple[str, Tuple[Tuple[str, str], ...]]


def _escape_label_value(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _escape_help(h: str) -> str:
    return h.replace("\\", "\\\\").replace("\n", "\\n")


def _format_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v) if v != int(v) else str(int(v))


def render_text(registry: Optional[Registry] = None) -> str:
    """Render every metric in *registry* in Prometheus text format."""
    reg = registry if registry is not None else REGISTRY
    lines = []
    for metric in reg.collect():
        if metric.help:
            lines.append(f"# HELP {metric.name} {_escape_help(metric.help)}")
        lines.append(f"# TYPE {metric.name} {metric.kind}")
        for suffix, labelpairs, value in metric.samples():
            if labelpairs:
                body = ",".join(
                    f'{k}="{_escape_label_value(v)}"' for k, v in labelpairs)
                lines.append(
                    f"{metric.name}{suffix}{{{body}}} {_format_value(value)}")
            else:
                lines.append(
                    f"{metric.name}{suffix} {_format_value(value)}")
    return "\n".join(lines) + "\n"


def _parse_labels(body: str, where: str) -> Tuple[Tuple[str, str], ...]:
    """Parse the inside of a ``{...}`` label block, honouring escapes."""
    pairs = []
    i, n = 0, len(body)
    while i < n:
        eq = body.index("=", i)
        name = body[i:eq].strip()
        if body[eq + 1] != '"':
            raise ValueError(f"{where}: unquoted label value")
        j = eq + 2
        out = []
        while True:
            ch = body[j]
            if ch == "\\":
                nxt = body[j + 1]
                out.append({"n": "\n", "\\": "\\", '"': '"'}.get(nxt, nxt))
                j += 2
            elif ch == '"':
                break
            else:
                out.append(ch)
                j += 1
        pairs.append((name, "".join(out)))
        i = j + 1
        if i < n and body[i] == ",":
            i += 1
    return tuple(sorted(pairs))


def parse_text(text: str) -> Dict[SeriesKey, float]:
    """Inverse of :func:`render_text`: series key -> value.

    Keys are ``(name, sorted ((label, value), ...))`` — histogram bucket
    samples appear under ``<name>_bucket`` with their ``le`` label.
    """
    out: Dict[SeriesKey, float] = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        where = f"line {lineno}"
        if "{" in line:
            name, rest = line.split("{", 1)
            body, tail = rest.rsplit("}", 1)
            labels = _parse_labels(body, where)
            value_str = tail.strip()
        else:
            parts = line.split()
            if len(parts) != 2:
                raise ValueError(f"{where}: malformed sample {line!r}")
            name, value_str = parts
            labels = ()
        out[(name.strip(), labels)] = float(value_str)
    return out


def snapshot(registry: Optional[Registry] = None) -> Dict[str, float]:
    """Flatten the registry to ``{'name{l="v"}': value}`` — a JSON-able
    snapshot for ``BENCH_*.json`` records."""
    flat: Dict[str, float] = {}
    for (name, labels), value in parse_text(render_text(registry)).items():
        if labels:
            body = ",".join(f'{k}="{v}"' for k, v in labels)
            flat[f"{name}{{{body}}}"] = value
        else:
            flat[name] = value
    return flat


class _Handler(BaseHTTPRequestHandler):
    registry: Registry  # set per-server by MetricsServer

    def do_GET(self) -> None:  # noqa: N802 — http.server API
        if self.path.split("?", 1)[0] not in ("/metrics", "/"):
            self.send_error(404)
            return
        try:
            body = render_text(self.registry).encode("utf-8")
            ctype = CONTENT_TYPE
        except Exception as exc:  # surface scrape bugs to the scraper
            body = json.dumps({"error": repr(exc)}).encode("utf-8")
            self.send_response(500)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt: str, *args: object) -> None:
        pass  # scrapes are not worth a log line each


class MetricsServer:
    """Minimal ``/metrics`` endpoint on a daemon thread.

    ``port=0`` binds an ephemeral port; read the real one from ``.port``.
    """

    def __init__(self, registry: Optional[Registry] = None,
                 host: str = "127.0.0.1", port: int = 0):
        reg = registry if registry is not None else REGISTRY
        handler = type("BoundHandler", (_Handler,), {"registry": reg})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def start(self) -> "MetricsServer":
        t = threading.Thread(target=self._httpd.serve_forever,
                             name="metrics-http", daemon=True)
        t.start()
        self._thread = t
        return self

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc: object) -> None:
        self.close()


def main(argv: Optional[list] = None) -> int:
    """Serve the process-wide registry — registered as a child entrypoint
    with the import-graph checker, which is what *enforces* that this
    module (and everything it pulls in) stays jax-free."""
    import argparse
    import time

    ap = argparse.ArgumentParser(description="serve /metrics")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    args = ap.parse_args(argv)
    with MetricsServer(host=args.host, port=args.port) as srv:
        print(srv.url, flush=True)
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            pass
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
