"""Dependency-free observability: metrics registry, Prometheus text
exposition, and sampled cross-process request tracing.

Everything in this package is stdlib-only and importable without jax —
shard-server children (gated by the import-graph checker) serve their own
``/metrics`` endpoint from it.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               REGISTRY, get_registry)
from repro.obs.trace import Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
    "get_registry", "Span", "Tracer",
]
