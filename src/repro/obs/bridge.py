"""Bridges: the repo's existing stat silos -> the metrics registry.

Each silo (``ServerStats``, ``FabricMetrics``, ``TierStats``,
``VersionWindow``) stays the single source of truth for its counters;
a bridge registers a *collector* on the registry that pulls a fresh
snapshot at scrape time and pushes it into registry metrics.  Between
scrapes the silos pay nothing.

The ``*_METRICS`` module-level dict literals are the catalog: silo field
-> exposition name.  ``tools/analyze``'s metrics-coverage checker parses
them straight out of this file and enforces (a) every silo field is
mapped (or explicitly exempted), (b) every exposition name is unique,
and (c) every name is documented in ``docs/observability.md``.

Naming convention (load-bearing): names ending ``_total`` render as
Prometheus counters; everything else renders as a gauge.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

from repro.obs.metrics import Registry

# -- catalog: silo field -> exposition name ---------------------------------
# serve/scheduler.StatsSnapshot (one QueryServer's totals)
SERVER_STATS_METRICS = {
    "submitted": "repro_server_requests_submitted_total",
    "completed": "repro_server_requests_completed_total",
    "failed": "repro_server_requests_failed_total",
    "shed_queue_full": "repro_server_shed_queue_full_total",
    "shed_deadline": "repro_server_shed_deadline_total",
    "batches": "repro_server_batches_total",
    "launches": "repro_server_launches_total",
    "keys_requested": "repro_server_keys_requested_total",
    "keys_deviceside": "repro_server_keys_deviceside_total",
    "service_sum_ms": "repro_server_service_time_ms_total",
    "deadline_hits": "repro_server_deadline_hits_total",
    "deadline_misses": "repro_server_deadline_misses_total",
    "p50_ms": "repro_server_latency_p50_ms",
    "p99_ms": "repro_server_latency_p99_ms",
    "mean_occupancy": "repro_server_batch_occupancy",
    "coalesce_rate": "repro_server_coalesce_rate",
    "shed_rate": "repro_server_shed_rate",
}

# serve/scheduler.ClassSnapshot (per-QoS slice; label: qos)
CLASS_STATS_METRICS = {
    "submitted": "repro_server_class_requests_submitted_total",
    "completed": "repro_server_class_requests_completed_total",
    "failed": "repro_server_class_requests_failed_total",
    "shed_queue_full": "repro_server_class_shed_queue_full_total",
    "shed_deadline": "repro_server_class_shed_deadline_total",
    "latency_sum_ms": "repro_server_class_latency_sum_ms_total",
    "p50_ms": "repro_server_class_latency_p50_ms",
    "p99_ms": "repro_server_class_latency_p99_ms",
    "shed_rate": "repro_server_class_shed_rate",
}

# serve/fabric.FabricCounts (the router's counter set)
FABRIC_METRICS = {
    "queries": "repro_fabric_queries_total",
    "sub_queries": "repro_fabric_sub_queries_total",
    "updates": "repro_fabric_updates_total",
    "consistent_batches": "repro_fabric_consistent_batches_total",
    "mixed_version_averted": "repro_fabric_mixed_version_averted_total",
    "version_retries": "repro_fabric_version_retries_total",
    "failovers": "repro_fabric_failovers_total",
    "replica_failures": "repro_fabric_replica_failures_total",
    "respawns": "repro_fabric_respawns_total",
    "snapshots": "repro_fabric_snapshots_total",
}

# core/tiering.TierStats (per hybrid hot/cold table; label: table)
TIER_STATS_METRICS = {
    "lookups": "repro_tier_lookups_total",
    "hot_hits": "repro_tier_hot_hits_total",
    "cold_misses": "repro_tier_cold_misses_total",
    "not_found": "repro_tier_not_found_total",
    "admissions": "repro_tier_admissions_total",
    "evictions": "repro_tier_evictions_total",
    "cold_bytes_read": "repro_tier_cold_bytes_read_total",
    "hot_bytes_read": "repro_tier_hot_bytes_read_total",
    "garbage_bytes": "repro_tier_garbage_bytes",
    "cold_file_bytes": "repro_tier_cold_file_bytes",
    "compactions": "repro_tier_compactions_total",
    "compaction_rows_rewritten": "repro_tier_compaction_rows_rewritten_total",
    "compaction_bytes_reclaimed": "repro_tier_compaction_bytes_reclaimed_total",
}

# derived from TierStats fields at scrape time (ratios the paper quotes)
TIER_DERIVED_METRICS = {
    "hit_rate": "repro_tier_hot_hit_rate",
    "garbage_fraction": "repro_tier_garbage_fraction",
}

# core/versioning.VersionWindow protocol counters
WINDOW_METRICS = {
    "pins": "repro_version_pin_served_total",
    "nacks": "repro_version_pin_nacks_total",
    "publishes": "repro_version_window_publishes_total",
    "evictions": "repro_version_window_evictions_total",
}

# stream/pipeline.StreamSnapshot (the streaming update pipeline's silo)
STREAM_METRICS = {
    "events_consumed": "repro_stream_events_consumed_total",
    "trainer_steps": "repro_stream_trainer_steps_total",
    "deltas_published": "repro_stream_deltas_published_total",
    "rows_upserted": "repro_stream_rows_upserted_total",
    "profile_flushes": "repro_stream_profile_flushes_total",
    "trending_refreshes": "repro_stream_trending_refreshes_total",
    "events_shed": "repro_stream_events_shed_total",
    "truncations_recovered": "repro_stream_truncations_recovered_total",
    "staleness_violations": "repro_stream_staleness_violations_total",
    "min_version_violations": "repro_stream_min_version_violations_total",
    "freshness_samples": "repro_stream_freshness_samples",
    "freshness_p50_ms": "repro_stream_freshness_p50_ms",
    "freshness_p99_ms": "repro_stream_freshness_p99_ms",
    "updates_per_s": "repro_stream_updates_per_s",
}

# the event-append -> servable-version latency distribution (observed by
# StreamStats.on_freshness, wired in bridge_stream_stats)
STREAM_HISTOGRAM_METRICS = {
    "freshness_seconds": "repro_stream_freshness_seconds",
}

# traffic/driver.TrafficSnapshot (one load-generator run's totals)
TRAFFIC_METRICS = {
    "offered": "repro_traffic_requests_offered_total",
    "completed": "repro_traffic_requests_completed_total",
    "shed": "repro_traffic_requests_shed_total",
    "failed": "repro_traffic_requests_failed_total",
    "slo_hits": "repro_traffic_slo_hits_total",
    "slo_misses": "repro_traffic_slo_misses_total",
    "attainment": "repro_traffic_slo_attainment",
    "offered_rps": "repro_traffic_offered_rps",
    "dispatch_lag_ms": "repro_traffic_dispatch_lag_ms",
    "p50_ms": "repro_traffic_latency_p50_ms",
    "p99_ms": "repro_traffic_latency_p99_ms",
}

# traffic/driver.ClassTraffic (per-QoS slice; label: qos)
TRAFFIC_CLASS_METRICS = {
    "offered": "repro_traffic_class_requests_offered_total",
    "completed": "repro_traffic_class_requests_completed_total",
    "shed": "repro_traffic_class_requests_shed_total",
    "failed": "repro_traffic_class_requests_failed_total",
    "slo_hits": "repro_traffic_class_slo_hits_total",
    "slo_misses": "repro_traffic_class_slo_misses_total",
    "attainment": "repro_traffic_class_slo_attainment",
    "p50_ms": "repro_traffic_class_latency_p50_ms",
    "p99_ms": "repro_traffic_class_latency_p99_ms",
}

# traffic/controller.ControllerSnapshot (the adaptive control plane)
CONTROLLER_METRICS = {
    "ticks": "repro_traffic_ctl_ticks_total",
    "grows": "repro_traffic_ctl_grows_total",
    "shrinks": "repro_traffic_ctl_shrinks_total",
    "holds": "repro_traffic_ctl_holds_total",
    "hot_adjustments": "repro_traffic_ctl_hot_adjustments_total",
    "compact_adjustments": "repro_traffic_ctl_compact_adjustments_total",
    "hot_fraction": "repro_traffic_ctl_hot_fraction",
    "compact_threshold": "repro_traffic_ctl_compact_threshold",
}

# traffic/controller.LaneKnobs (per-lane live close rules; label: qos)
LANE_KNOB_METRICS = {
    "max_batch_keys": "repro_traffic_ctl_lane_max_batch_keys",
    "max_batch_requests": "repro_traffic_ctl_lane_max_batch_requests",
    "max_wait_ms": "repro_traffic_ctl_lane_max_wait_ms",
}


def _emit(registry: Registry, mapping: Dict[str, str], data: Dict,
          labels: Dict[str, str]) -> None:
    """Push one snapshot dict through a field->name mapping.  ``_total``
    names render as counters (via the bridge-only ``set_total`` face),
    the rest as gauges."""
    labelnames = tuple(sorted(labels))
    for field, name in mapping.items():
        value = data.get(field)
        if not isinstance(value, (int, float)) or isinstance(value, bool):
            continue
        if name.endswith("_total"):
            if math.isnan(value):
                continue              # a counter can't adopt NaN
            registry.counter(name, labelnames=labelnames) \
                .set_total(value, **labels)
        else:
            registry.gauge(name, labelnames=labelnames) \
                .set(value, **labels)


def _as_dict(snap) -> Dict:
    return snap if isinstance(snap, dict) else dataclasses.asdict(snap)


def _emit_server(registry: Registry, snap,
                 labels: Dict[str, str]) -> None:
    data = _as_dict(snap)
    _emit(registry, SERVER_STATS_METRICS, data, labels)
    for qos, cls in (data.get("per_class") or {}).items():
        _emit(registry, CLASS_STATS_METRICS, _as_dict(cls),
              {**labels, "qos": str(qos)})


def _emit_tiers(registry: Registry, tiers: Dict[str, Dict],
                labels: Dict[str, str]) -> None:
    for table, data in tiers.items():
        data = _as_dict(data)
        tl = {**labels, "table": str(table)}
        _emit(registry, TIER_STATS_METRICS, data, tl)
        lookups = data.get("lookups") or 0
        total = data.get("cold_file_bytes") or 0
        derived = {
            "hit_rate": (data.get("hot_hits", 0) / lookups)
            if lookups else 0.0,
            "garbage_fraction": (data.get("garbage_bytes", 0) / total)
            if total else 0.0,
        }
        _emit(registry, TIER_DERIVED_METRICS, derived, tl)


# -- bridge registrations ----------------------------------------------------
def bridge_server_stats(registry: Registry,
                        snapshot_fn: Callable[[], object],
                        labels: Optional[Dict[str, str]] = None
                        ) -> Callable[[], None]:
    """Bridge a ``QueryServer``'s stats (``snapshot_fn`` returning a
    ``StatsSnapshot``/dict, or None to skip a scrape)."""
    fixed = dict(labels or {})

    def collect() -> None:
        snap = snapshot_fn()
        if snap is not None:
            _emit_server(registry, snap, fixed)

    registry.register_collector(collect)
    return collect


def bridge_tier_stats(registry: Registry,
                      stats_fn: Callable[[], Dict[str, Dict]],
                      labels: Optional[Dict[str, str]] = None
                      ) -> Callable[[], None]:
    """Bridge per-table ``TierStats`` (``stats_fn`` returning
    ``{table: {field: value}}`` — e.g. ``StoreBackend.tier_stats``)."""
    fixed = dict(labels or {})

    def collect() -> None:
        tiers = stats_fn()
        if tiers:
            _emit_tiers(registry, tiers, fixed)

    registry.register_collector(collect)
    return collect


def bridge_version_window(registry: Registry, window
                          ) -> Callable[[], None]:
    """Bridge a ``VersionWindow``'s protocol counters (pins served, NACKs,
    publishes, retention evictions)."""

    def collect() -> None:
        _emit(registry, WINDOW_METRICS, window.counters(), {})

    registry.register_collector(collect)
    return collect


def bridge_stream_stats(registry: Registry, stats
                        ) -> Callable[[], None]:
    """Bridge a streaming pipeline's ``StreamStats`` silo: its snapshot
    counters at scrape time, plus every freshness sample streamed into
    the ``repro_stream_freshness_seconds`` histogram as it is observed
    (the silo's ``on_freshness`` hook)."""
    hist = registry.histogram(
        STREAM_HISTOGRAM_METRICS["freshness_seconds"],
        help="event-append -> servable-version latency (s)")
    stats.on_freshness = hist.observe

    def collect() -> None:
        _emit(registry, STREAM_METRICS,
              dataclasses.asdict(stats.snapshot()), {})

    registry.register_collector(collect)
    return collect


def bridge_traffic_stats(registry: Registry,
                         snapshot_fn: Callable[[], object],
                         labels: Optional[Dict[str, str]] = None
                         ) -> Callable[[], None]:
    """Bridge a load-generator run's ``TrafficStats`` (``snapshot_fn``
    returning a ``TrafficSnapshot``/dict): run totals plus the per-QoS
    slices under the ``qos`` label — offered load and SLO attainment as
    the *client* saw them, the counterpart to the server-side silo."""
    fixed = dict(labels or {})

    def collect() -> None:
        snap = snapshot_fn()
        if snap is None:
            return
        data = _as_dict(snap)
        _emit(registry, TRAFFIC_METRICS, data, fixed)
        for qos, cls in (data.get("per_class") or {}).items():
            _emit(registry, TRAFFIC_CLASS_METRICS, _as_dict(cls),
                  {**fixed, "qos": str(qos)})

    registry.register_collector(collect)
    return collect


def bridge_controller(registry: Registry, controller,
                      labels: Optional[Dict[str, str]] = None
                      ) -> Callable[[], None]:
    """Bridge an ``AdaptiveController``: decision counters, store knobs,
    and each lane's live close rules under the ``qos`` label — a scrape
    shows where the control plane has steered the serving config."""
    fixed = dict(labels or {})

    def collect() -> None:
        snap = controller.snapshot()
        data = _as_dict(snap)
        _emit(registry, CONTROLLER_METRICS, data, fixed)
        for qos, knobs in (data.get("per_lane") or {}).items():
            _emit(registry, LANE_KNOB_METRICS, _as_dict(knobs),
                  {**fixed, "qos": str(qos)})

    registry.register_collector(collect)
    return collect


def bridge_fabric_metrics(registry: Registry, metrics
                          ) -> Callable[[], None]:
    """Bridge a router's ``FabricMetrics`` counter set alone (the full
    fabric view including shard-side silos is ``bridge_router``)."""

    def collect() -> None:
        _emit(registry, FABRIC_METRICS,
              dataclasses.asdict(metrics.snapshot()), {})

    registry.register_collector(collect)
    return collect


def bridge_router(registry: Registry, router,
                  stats_timeout_s: float = 5.0) -> Callable[[], None]:
    """The fabric's whole metrics surface behind one parent-side registry:
    the router's own counters plus, via the KIND_STATS RPC, every live
    replica's serving stats (label ``shard``, per-QoS under ``qos``) and
    tier counters (labels ``shard``, ``table``).  A scrape mid-failover
    degrades to whatever replicas answer — it never raises."""

    def collect() -> None:
        _emit(registry, FABRIC_METRICS,
              dataclasses.asdict(router.metrics.snapshot()), {})
        try:
            shards = router.collect_shard_stats(timeout_s=stats_timeout_s)
        except Exception:
            return                     # router mid-close; keep the scrape
        for shard_key, silo in shards.items():
            labels = {"shard": str(shard_key)}
            if silo.get("server"):
                _emit_server(registry, silo["server"], labels)
            if silo.get("tiers"):
                _emit_tiers(registry, silo["tiers"], labels)

    registry.register_collector(collect)
    return collect
