"""Checkpoint/restore with elastic resharding (fault-tolerance substrate).

Format: one .npz of flattened leaves (path-keyed) + a JSON sidecar (step,
config name, mesh shape at save time, rng state, data cursor).  Restore
device_puts every leaf against the *current* mesh's NamedShardings — the mesh
may differ from the one that saved (elastic scaling / failed-node restart);
resharding is free because leaves are saved as full logical arrays.

At real multi-host scale the same layout maps onto per-host shard files keyed
by (leaf, shard-index) — the path-keyed flat layout is chosen so that change
is additive (see DESIGN.md §6).  Async save: the host copy happens on a
worker thread so the step loop isn't blocked (jax arrays are snapshotted via
np.asarray before the thread starts).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding

SEP = "::"


def _flatten(tree) -> dict:
    """npz cannot store ml_dtypes (bf16/fp8) — byte-view them and keep the
    dtype name alongside so restore can view back."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":          # exotic (bf16, fp8, ...)
            out[key + "@dtype"] = np.frombuffer(
                str(arr.dtype).encode(), dtype=np.uint8)
            arr = arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,))
        out[key] = arr
    return out


def _unflatten_leaf(data, key):
    arr = data[key]
    dkey = key + "@dtype"
    if dkey in data:
        import ml_dtypes                            # jax dependency
        dtype = np.dtype(bytes(data[dkey]).decode())
        arr = arr.view(dtype).reshape(arr.shape[:-1])
    return arr


def save(path: str, *, params, opt_state=None, step: int = 0,
         meta: Optional[dict] = None, async_save: bool = False
         ) -> Optional[threading.Thread]:
    os.makedirs(path, exist_ok=True)
    blobs = {"params" + SEP + k: v for k, v in _flatten(params).items()}
    if opt_state is not None:
        blobs.update({"opt" + SEP + k: v
                      for k, v in _flatten(opt_state).items()})
    sidecar = {"step": int(step), "meta": meta or {},
               "n_leaves": len(blobs)}

    def write():
        np.savez(os.path.join(path, "ckpt.npz"), **blobs)
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump(sidecar, f)
        os.replace(os.path.join(path, "meta.json"),
                   os.path.join(path, "META.json"))   # commit marker

    if async_save:
        t = threading.Thread(target=write)
        t.start()
        return t
    write()
    return None


def exists(path: str) -> bool:
    return os.path.exists(os.path.join(path, "META.json"))


def restore(path: str, *, params_like, opt_like=None, mesh=None,
            param_specs=None, opt_specs=None):
    """Returns (params, opt_state, step, meta).  ``*_like`` give the target
    tree structure; ``*_specs`` (PartitionSpec trees) + ``mesh`` reshard onto
    the current topology."""
    with open(os.path.join(path, "META.json")) as f:
        sidecar = json.load(f)
    data = np.load(os.path.join(path, "ckpt.npz"))

    def rebuild(prefix, like, specs):
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        flat_specs = (jax.tree_util.tree_flatten(specs)[0]
                      if specs is not None else [None] * len(flat))
        leaves = []
        for (p, leaf), spec in zip(flat, flat_specs):
            key = prefix + SEP + SEP.join(
                str(getattr(q, "key", getattr(q, "idx",
                                              getattr(q, "name", q))))
                for q in p)
            arr = _unflatten_leaf(data, key)
            if mesh is not None and spec is not None:
                arr = jax.device_put(arr, NamedSharding(mesh, spec))
            else:
                arr = jax.device_put(arr)
            leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                          else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = rebuild("params", params_like, param_specs)
    opt_state = (rebuild("opt", opt_like, opt_specs)
                 if opt_like is not None else None)
    return params, opt_state, sidecar["step"], sidecar["meta"]
