"""Per-family train steps: loss -> grad -> clipped sharded update.

A train step is a pure function (params, opt_state, step, batch) ->
(params', opt_state', step+1, metrics); the dry-run lowers exactly this
function, so the roofline terms include backward pass and optimizer."""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt
from repro.models import common as cm
from repro.models import lm as lm_mod
from repro.models import recsys as rec_mod
from repro.models import gnn as gnn_mod


def make_train_step(loss_fn: Callable, opt_cfg: opt.OptConfig,
                    accum_steps: int = 1,
                    delta_ids_fn: Optional[Callable] = None):
    """loss_fn(params, batch) -> (loss, metrics).

    ``accum_steps`` > 1 splits the batch into microbatches scanned with
    gradient accumulation — activation memory scales with the microbatch
    while optimizer/collective cost is unchanged (the standard way to fit
    a big global batch per device; §Perf B2).

    ``delta_ids_fn(batch) -> {table_name: ids}`` adds the embedding rows
    this step touched to ``metrics["delta_ids"]`` — the per-step delta a
    driver accumulates into incremental serving publishes
    (engine.publish_delta; the paper's Update Subsystem train->serve
    path)."""

    def train_step(params, opt_state, step, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = jax.lax.scan(body, (g0, jnp.float32(0)),
                                             micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = jax.tree.map(lambda m: m[-1], ms)
        new_params, new_state, gnorm = opt.apply_updates(
            params, grads, opt_state, opt_cfg, step + 1)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["loss"] = loss
        if delta_ids_fn is not None:
            metrics["delta_ids"] = delta_ids_fn(batch)
        return new_params, new_state, step + 1, metrics

    return train_step


# ---------------------------------------------------------------------------
# sparse-embedding train step (recsys; §Perf B1)
#
# Dense autodiff through jnp.take produces full [V, D] cotangents per table —
# tens of GB of zeros per step at 10⁸ rows.  Here: gather rows -> grad w.r.t.
# the gathered rows only -> scatter row-wise-Adagrad into the touched rows.
# (Duplicate ids within a batch scatter-accumulate into the same Adagrad row;
# matches TF/IndexedSlices semantics up to per-occurrence accumulator order.)
# ---------------------------------------------------------------------------
def make_sparse_recsys_train_step(cfg, mesh, mi, opt_cfg: opt.OptConfig,
                                  emit_deltas: bool = False):
    """``emit_deltas=True`` adds ``metrics["delta_ids"]`` — the raw (possibly
    repeated, -1-padded) row ids each table scattered into this step, for the
    incremental-publish pipeline.  The host dedupes; shapes stay static."""
    from repro.models import recsys as rec

    def train_step(params, opt_state, step, batch):
        ids_map = rec.table_ids(cfg, batch)
        table_names = sorted({t for t, _ in ids_map.values()})
        dense = {k: v for k, v in params.items() if k not in table_names}
        rows = {k: jnp.take(params[t], jnp.maximum(ids, 0), axis=0)
                * (ids >= 0).astype(params[t].dtype)[..., None]
                for k, (t, ids) in ids_map.items()}

        def loss_on(dense_p, rows_p):
            merged = dict(dense_p)
            for t in table_names:       # forward uses rows, not tables
                merged[t] = params[t]
            return rec.recsys_loss_rows(merged, cfg, batch, rows_p, mi)

        (loss, metrics), (g_dense, g_rows) = jax.value_and_grad(
            loss_on, argnums=(0, 1), has_aux=True)(dense, rows)

        new_dense, new_dense_state, gnorm = opt.apply_updates(
            dense, g_dense, {k: opt_state[k] for k in dense},
            opt_cfg, step + 1)

        new_params = dict(new_dense)
        new_state = dict(new_dense_state)
        for t in table_names:
            table = params[t]
            acc = opt_state[t]["acc"]
            for k, (tname, ids) in ids_map.items():
                if tname != t:
                    continue
                g = g_rows[k].astype(jnp.float32)
                flat_ids = jnp.maximum(ids.reshape(-1), 0)
                valid = (ids.reshape(-1) >= 0).astype(jnp.float32)
                gf = g.reshape(-1, g.shape[-1]) * valid[:, None]
                row_sq = jnp.mean(gf * gf, axis=-1)
                acc = acc.at[flat_ids].add(row_sq)
                scale = opt_cfg.lr / (jnp.sqrt(
                    jnp.take(acc, flat_ids)) + opt_cfg.eps)
                table = table.at[flat_ids].add(
                    (-scale[:, None] * gf).astype(table.dtype))
            new_params[t] = table
            new_state[t] = {"acc": acc}
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        if emit_deltas:
            metrics["delta_ids"] = {
                t: jnp.concatenate([ids.reshape(-1)
                                    for k, (tn, ids) in sorted(ids_map.items())
                                    if tn == t])
                for t in table_names}
        return new_params, new_state, step + 1, metrics

    return train_step


# ---------------------------------------------------------------------------
# family loss adapters
# ---------------------------------------------------------------------------
def lm_loss_fn(cfg, mesh, mi):
    def fn(params, batch):
        return lm_mod.lm_loss(params, cfg, batch, mesh, mi)
    return fn


def recsys_loss_fn(cfg, mesh, mi):
    def fn(params, batch):
        return rec_mod.recsys_loss(params, cfg, batch, mi)
    return fn


def gnn_loss_fn(cfg, mesh, mi, regime: str):
    def fn(params, batch):
        return gnn_mod.gnn_loss(params, cfg, batch, mi, regime)
    return fn
