"""Optimizers over param pytrees, sharding-aware.

Three rules, chosen per-leaf by path (train/train_step.py wires them):

  * ``adam``          — fp32 m/v; dense towers and small models.
  * ``adafactor``     — factored second moment (row/col fp32) + bf16
                        momentum; the 340B/671B LMs (PaLM-style memory diet —
                        10.5 GB/device instead of 21 GB for DeepSeek-V3 on a
                        256-chip pod; see DESIGN.md §6 / EXPERIMENTS.md).
  * ``adagrad_rows``  — row-wise Adagrad for embedding tables (industry
                        standard for sparse features; one fp32 accumulator
                        per row, not per element).

Optimizer state inherits each param's PartitionSpec (fully-sharded FSDP
params ⇒ fully-sharded optimizer state ⇒ ZeRO comes from the specs, not from
bespoke machinery).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common as cm


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    table_rule: str = "adagrad_rows"
    dense_rule: str = "adam"          # adam | adafactor


def rule_for_path(path: tuple, cfg: OptConfig) -> str:
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    flat = "/".join(str(n) for n in names)
    if "table" in flat or "embed" in flat:
        return cfg.table_rule
    return cfg.dense_rule


# ---------------------------------------------------------------------------
# state init (per-leaf)
# ---------------------------------------------------------------------------
def _leaf_state(rule: str, p: jnp.ndarray) -> dict:
    if rule == "adam":
        return {"m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32)}
    if rule == "adafactor":
        st = {"m": jnp.zeros(p.shape, jnp.bfloat16)}
        if p.ndim >= 2:
            st["vr"] = jnp.zeros(p.shape[:-1], jnp.float32)       # row
            st["vc"] = jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                 jnp.float32)                     # col
        else:
            st["v"] = jnp.zeros(p.shape, jnp.float32)
        return st
    if rule == "adagrad_rows":
        return {"acc": jnp.zeros(p.shape[:1], jnp.float32)}
    raise ValueError(rule)


def _leaf_state_spec(rule: str, spec: P, p) -> dict:
    if rule == "adam":
        return {"m": spec, "v": spec}
    if rule == "adafactor":
        st = {"m": spec}
        if p.ndim >= 2:
            st["vr"] = P(*spec[:-1])
            st["vc"] = P(*spec[:-2], *spec[-1:])
        else:
            st["v"] = spec
        return st
    if rule == "adagrad_rows":
        return {"acc": P(*spec[:1])}
    raise ValueError(rule)


def init_opt_state(params, cfg: OptConfig):
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params),
        [_leaf_state(rule_for_path(path, cfg), p) for path, p in flat])


def opt_state_specs(params_or_shapes, specs, cfg: OptConfig):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_or_shapes)
    flat_specs = jax.tree_util.tree_flatten(specs)[0]
    out = [_leaf_state_spec(rule_for_path(path, cfg), sp, p)
           for (path, p), sp in zip(flat, flat_specs)]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# updates (per-leaf)
# ---------------------------------------------------------------------------
def _adam_update(p, g, st, cfg: OptConfig, step):
    g = g.astype(jnp.float32)
    m = cfg.b1 * st["m"] + (1 - cfg.b1) * g
    v = cfg.b2 * st["v"] + (1 - cfg.b2) * g * g
    mhat = m / (1 - cfg.b1 ** step)
    vhat = v / (1 - cfg.b2 ** step)
    upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
    if cfg.weight_decay:
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
    new_p = (p.astype(jnp.float32) - cfg.lr * upd).astype(p.dtype)
    return new_p, {"m": m, "v": v}


def _adafactor_update(p, g, st, cfg: OptConfig, step):
    g = g.astype(jnp.float32)
    new_st = {}
    if "vr" in st:
        decay = 1.0 - 1.0 / jnp.maximum(step, 1.0) ** 0.8
        vr = decay * st["vr"] + (1 - decay) * jnp.mean(g * g, axis=-1)
        vc = decay * st["vc"] + (1 - decay) * jnp.mean(g * g, axis=-2)
        new_st["vr"], new_st["vc"] = vr, vc
        denom = jnp.sqrt(
            vr[..., None] * vc[..., None, :]
            / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None],
                          1e-30)) + cfg.eps
    else:
        decay = 1.0 - 1.0 / jnp.maximum(step, 1.0) ** 0.8
        v = decay * st["v"] + (1 - decay) * g * g
        new_st["v"] = v
        denom = jnp.sqrt(v) + cfg.eps
    upd = g / denom
    m = cfg.b1 * st["m"].astype(jnp.float32) + (1 - cfg.b1) * upd
    new_st["m"] = m.astype(jnp.bfloat16)
    new_p = (p.astype(jnp.float32) - cfg.lr * m).astype(p.dtype)
    return new_p, new_st


def _adagrad_rows_update(p, g, st, cfg: OptConfig, step):
    g = g.astype(jnp.float32)
    row_sq = jnp.mean(g * g, axis=tuple(range(1, g.ndim)))
    acc = st["acc"] + row_sq
    scale = cfg.lr / (jnp.sqrt(acc) + cfg.eps)
    new_p = (p.astype(jnp.float32)
             - scale.reshape((-1,) + (1,) * (g.ndim - 1)) * g).astype(p.dtype)
    return new_p, {"acc": acc}


_UPDATES: dict[str, Callable] = {
    "adam": _adam_update,
    "adafactor": _adafactor_update,
    "adagrad_rows": _adagrad_rows_update,
}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, opt_state, cfg: OptConfig, step):
    """step: int32 scalar (1-based).  Returns (new_params, new_state, gnorm)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0
    stepf = step.astype(jnp.float32)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_s = treedef.flatten_up_to(opt_state)
    new_p, new_s = [], []
    for (path, p), g, st in zip(flat, flat_g, flat_s):
        rule = rule_for_path(path, cfg)
        np_, ns = _UPDATES[rule](p, g * clip, st, cfg, stepf)
        new_p.append(np_)
        new_s.append(ns)
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            jax.tree_util.tree_unflatten(treedef, new_s), gnorm)
